"""ExecutionConfig: the one-value execution API and its deprecation shim.

The config dataclass replaces eight interacting Engine kwargs; these tests
pin the preset matrix, the validation rules, the legacy-kwarg shim (warns
but behaves identically for one release) and the ``make_engine`` dispatch.
"""

import dataclasses

import numpy as np
import pytest

from conformance import make_pipeline_topo
from repro.engine import Engine, ExecutionConfig, make_engine


def test_preset_matrix():
    assert ExecutionConfig.oracle() == ExecutionConfig(
        queue_impl="deque", use_fn_seg=False, use_schema=False
    )
    assert ExecutionConfig.seg() == ExecutionConfig(use_schema=False)
    assert ExecutionConfig.typed() == ExecutionConfig()
    jit = ExecutionConfig.jit()
    assert jit.use_fn_jit and not jit.use_superstep
    sstep = ExecutionConfig.superstep()
    assert sstep.use_fn_jit and sstep.use_superstep
    w = ExecutionConfig.workers(3)
    assert w.num_workers == 3 and w.use_schema and w.use_fn_seg
    from repro.engine.config import SHM_LANE_BYTES

    assert w.shm_lane_bytes == SHM_LANE_BYTES
    assert ExecutionConfig.workers(3, shm=0).shm_lane_bytes == 0


def test_config_names_match_conformance_labels():
    assert ExecutionConfig.typed().name == "soa+seg+schema"
    assert ExecutionConfig.seg().name == "soa+seg"
    assert ExecutionConfig(use_fn_seg=False, use_schema=False).name == "soa+fn"
    assert ExecutionConfig.oracle().name == "deque+fn"
    assert ExecutionConfig.jit().name == "soa+seg+schema+jit"
    assert ExecutionConfig.superstep().name == "soa+seg+schema+jit+superstep"
    assert ExecutionConfig.workers(2).name == "soa+seg+schema+workers"


def test_config_is_frozen_and_validated():
    with pytest.raises(dataclasses.FrozenInstanceError):
        ExecutionConfig().queue_impl = "deque"  # type: ignore[misc]
    with pytest.raises(ValueError, match="queue_impl"):
        ExecutionConfig(queue_impl="ring")
    with pytest.raises(ValueError, match="use_fn_jit requires"):
        ExecutionConfig(use_fn_jit=True, use_schema=False)
    with pytest.raises(ValueError, match="use_fn_jit requires"):
        ExecutionConfig(use_fn_jit=True, queue_impl="deque", use_schema=True)
    with pytest.raises(ValueError, match="use_superstep requires"):
        ExecutionConfig(use_superstep=True)
    with pytest.raises(ValueError, match="num_workers"):
        ExecutionConfig(num_workers=0)
    with pytest.raises(ValueError, match="shm_lane_bytes"):
        ExecutionConfig(shm_lane_bytes=-1)
    with pytest.raises(ValueError, match="shm_lane_bytes"):
        ExecutionConfig(shm_lane_bytes=32)
    with pytest.raises(ValueError, match="numpy tiers only"):
        ExecutionConfig(use_fn_jit=True, num_workers=2)


def test_replace_returns_new_validated_config():
    base = ExecutionConfig.typed()
    seg = base.replace(use_schema=False)
    assert seg == ExecutionConfig.seg()
    assert base.use_schema  # original untouched
    with pytest.raises(ValueError):
        ExecutionConfig.jit().replace(use_schema=False)


def test_legacy_kwargs_warn_and_map_onto_config():
    topo = make_pipeline_topo(8)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        eng = Engine(topo, 3, queue_impl="deque", use_fn_seg=False,
                     use_schema=False)
    assert eng.config == ExecutionConfig.oracle()
    with pytest.warns(DeprecationWarning):
        eng = Engine(make_pipeline_topo(8), 3, superstep=False,
                     use_fn_jit=False)
    assert eng.config == ExecutionConfig.typed()


def test_legacy_kwargs_behave_identically_to_config():
    def drive(eng):
        rng = np.random.default_rng(7)
        for t in range(6):
            keys = rng.integers(0, 500, size=80).astype(np.int64)
            eng.push_source("src", keys, rng.random(80), np.full(80, float(t)))
            eng.tick()
        for _ in range(4):
            eng.tick()
        return eng.metrics.sink_outputs, [s for _, s in eng.store.items()]

    a = drive(Engine(make_pipeline_topo(8), 3, config=ExecutionConfig.seg()))
    with pytest.warns(DeprecationWarning):
        b = drive(Engine(make_pipeline_topo(8), 3, use_schema=False))
    assert a == b


def test_config_and_legacy_kwargs_are_mutually_exclusive():
    with pytest.raises(TypeError, match="not both"):
        Engine(
            make_pipeline_topo(8),
            3,
            config=ExecutionConfig.typed(),
            use_schema=False,
        )


def test_engine_rejects_workers_config():
    with pytest.raises(ValueError, match="multi-worker"):
        Engine(make_pipeline_topo(8), 4, config=ExecutionConfig.workers(2))


def test_make_engine_dispatches_on_num_workers():
    eng = make_engine(make_pipeline_topo(8), 3, config=ExecutionConfig.typed())
    assert isinstance(eng, Engine)
    eng = make_engine(make_pipeline_topo(8), 3)  # default config
    assert eng.config == ExecutionConfig.typed()

    from repro.engine.cluster import ClusterEngine

    ceng = make_engine(
        make_pipeline_topo(8), 4, config=ExecutionConfig.workers(2)
    )
    try:
        assert isinstance(ceng, ClusterEngine)
        assert ceng.num_workers == 2
    finally:
        ceng.close()


def test_from_legacy_kwargs_rejects_unknown():
    with pytest.raises(TypeError, match="unknown execution kwargs"):
        ExecutionConfig.from_legacy_kwargs({"queue": "soa"})

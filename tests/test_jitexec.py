"""The compiled operator tier (engine/jitexec.py): kernels, recompilation
discipline, state coherence, and shard_map parity.

The differential conformance suite (tests/test_real_jobs_conformance.py)
already pins the jit configuration against the four oracles end to end;
this module pins the runtime's *mechanics*: padding-bucket compile counts
stay O(#buckets) across a long varied-batch run, keyed tables look up /
insert / grow correctly, interpreted↔compiled state stays coherent through
migrations, and the run-sharded shard_map execution matches the plain call.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conformance import make_pipeline_topo
from repro.data.jobs import real_job_2
from repro.data.synthetic import StreamSpec, airline_stream
from repro.engine import Engine, ExecutionConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _feed_pipeline(eng, sizes, *, seed=0):
    rng = np.random.default_rng(seed)
    for t, n in enumerate(sizes):
        keys = rng.integers(0, 10_000, size=n).astype(np.int64)
        eng.push_source("src", keys, rng.random(n), np.full(n, float(t)))
        eng.tick()
    for _ in range(6):
        eng.tick()


# ---------------------------------------------------------------------------
# recompilation discipline
# ---------------------------------------------------------------------------


def test_compiles_bounded_by_buckets_not_ticks():
    """A long run with wildly varied batch sizes compiles O(#buckets)
    programs: jit_calls grows with ticks, jit_compiles does not."""
    eng = Engine(
        make_pipeline_topo(8), 4, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    sizes = [7, 40, 900, 13, 260, 55, 1, 470, 33, 128] * 6  # 60 varied ticks
    _feed_pipeline(eng, sizes)
    m = eng.metrics
    assert m.jit_calls > 100  # 2 ops × 4 nodes × 60 ticks, minus empty drains
    # Buckets: tuple counts in {16..1024} (7 sizes) × run counts {4, 8} × 2
    # operators — far below the call count, and independent of tick count.
    assert m.jit_compiles < 40
    assert m.jit_compiles < m.jit_calls / 4
    assert m.jit_tuples > 0
    assert eng._jit.compile_seconds > 0.0


def test_second_engine_recompiles_nothing_globally():
    """The compile cache is keyed by the fn_jit object (module-level bodies):
    a second engine re-counts its own bucket set but hits jax's cache —
    runtime-level counts stay equal, not doubled, across engines."""
    sizes = [64, 64, 64, 64]
    eng1 = Engine(
        make_pipeline_topo(8), 2, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    _feed_pipeline(eng1, sizes)
    eng2 = Engine(
        make_pipeline_topo(8), 2, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    _feed_pipeline(eng2, sizes)
    assert eng2.metrics.jit_compiles == eng1.metrics.jit_compiles


def test_jit_requires_soa_and_schema():
    with pytest.raises(ValueError):
        ExecutionConfig(queue_impl="deque", use_fn_jit=True, use_schema=True)
    with pytest.raises(ValueError):
        ExecutionConfig(use_schema=False, use_fn_jit=True)


# ---------------------------------------------------------------------------
# keyed tables
# ---------------------------------------------------------------------------


def test_keyed_running_sum_matches_reference():
    """Direct kernel check against a python left-fold reference: lookups,
    first-occurrence insertion order, padding masks, duplicate codes."""
    jx = pytest.importorskip("repro.engine.jitexec")
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    n, nb, num_kg, cap = 50, 64, 3, 64
    codes = rng.integers(0, 6, size=nb).astype(np.int64) * 3 + np.arange(nb) % 3
    kg = (codes % 3).astype(np.int64)  # same code → same key group
    addends = rng.normal(size=nb)
    valid = np.arange(nb) < n
    table = jx.TableState(
        codes=jnp.full(cap, jx.EMPTY_CODE, dtype=jnp.int64),
        vals=jnp.zeros(cap),
        seq=jnp.zeros(cap, dtype=jnp.int64),
        owner=jnp.zeros(cap, dtype=jnp.int32),
        perm=jnp.arange(cap, dtype=jnp.int32),
        cnt=jnp.zeros((), dtype=jnp.int32),
        epoch=jnp.ones((), dtype=jnp.int64),
    )
    table2, running = jx.keyed_running_sum(
        table, jnp.asarray(codes), jnp.asarray(kg), jnp.asarray(addends),
        jnp.asarray(valid),
    )
    # A second call must continue from the first (sorted view incrementally
    # merged, sequence numbers monotone across epochs).
    table3, running2 = jx.keyed_running_sum(
        table2, jnp.asarray(codes), jnp.asarray(kg), jnp.asarray(addends),
        jnp.asarray(valid),
    )
    # Reference: sequential dicts per key group.
    dicts = [dict() for _ in range(num_kg)]
    ref = np.zeros(n)
    ref2 = np.zeros(n)
    for pass_out in (ref, ref2):
        for i in range(n):
            d = dicts[kg[i]]
            d[codes[i]] = d.get(codes[i], 0.0) + addends[i]
            pass_out[i] = d[codes[i]]
    np.testing.assert_allclose(np.asarray(running)[:n], ref, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(running2)[:n], ref2, rtol=1e-9, atol=1e-12
    )
    got = np.asarray(running)[:n]
    # Exactness of the first occurrence of every code.
    seen = set()
    for i in range(n):
        if codes[i] not in seen:
            seen.add(codes[i])
            assert got[i] == addends[i]
    # Table contents: per key group, codes in first-occurrence order by seq;
    # the sorted view is a valid permutation with codes ascending.
    for t in (table2, table3):
        t_codes = np.asarray(t.codes)
        t_seq = np.asarray(t.seq)
        t_owner = np.asarray(t.owner)
        cnt = int(t.cnt)
        assert cnt == sum(len(d) for d in dicts)
        for k in range(num_kg):
            mine = np.flatnonzero(t_owner[:cnt] == k)
            order = mine[np.argsort(t_seq[mine], kind="stable")]
            assert t_codes[order].tolist() == list(dicts[k])
        perm = np.asarray(t.perm)
        assert sorted(perm.tolist()) == list(range(len(perm)))
        assert np.all(np.diff(t_codes[perm]) >= 0)


def test_table_growth_past_initial_capacity():
    """More distinct keys than the initial 64-slot capacity: the runtime
    grows the tables (a new compile bucket) and the state stays equal to the
    interpreted oracle."""
    topo = real_job_2(keygroups_per_op=2)
    kw = dict(service_rate=1e9, seed=0, collect_sinks=False)
    jit_eng = Engine(real_job_2(keygroups_per_op=2), 2,
                     config=ExecutionConfig.jit(), **kw)
    seg_eng = Engine(topo, 2, **kw)
    stream = airline_stream(StreamSpec(rate=500.0, seed=3))
    batches = [next(stream) for _ in range(6)]
    for eng in (jit_eng, seg_eng):
        for k, v, ts in batches:
            eng.push_source("airline", k, v, ts)
            eng.tick()
        for _ in range(4):
            eng.tick()
        eng.end_period()
    caps = jit_eng._jit._by_op[2].caps  # sumdelay
    assert caps["sums"] > 64  # ~1000 (plane, year) pairs over 2 key groups
    for kg in range(topo.num_keygroups):
        a = jit_eng.store.get(kg)
        b = seg_eng.store.get(kg)
        assert list(a) == list(b)
        for name in a:
            if isinstance(a[name], dict):
                assert list(a[name]) == list(b[name])  # keys + order
                np.testing.assert_allclose(
                    list(a[name].values()),
                    list(b[name].values()),
                    rtol=1e-9,
                    atol=1e-9,
                )
            else:
                assert a[name] == b[name]


# ---------------------------------------------------------------------------
# interpreted ↔ compiled state coherence
# ---------------------------------------------------------------------------


def test_migration_blob_bytes_identical_on_integer_state():
    """serialize() of a jit-tier key group materializes the device columns
    into the oracle dict — on integer state the blob bytes are identical to
    the interpreted engine's."""
    sizes = [100, 80, 120]
    jit_eng = Engine(
        make_pipeline_topo(8), 2, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    seg_eng = Engine(make_pipeline_topo(8), 2, service_rate=1e9, seed=0)
    _feed_pipeline(jit_eng, sizes)
    _feed_pipeline(seg_eng, sizes)
    assert jit_eng.metrics.jit_calls > 0
    for kg in range(8, 24):  # mid + sink key groups
        assert jit_eng.serialize(kg) == seg_eng.serialize(kg)


def test_install_then_jit_resumes_from_installed_state():
    """install() marks the dict authoritative; the next jit call pushes it
    back into columns and continues from it."""
    eng = Engine(
        make_pipeline_topo(8), 2, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    _feed_pipeline(eng, [50, 50])
    kg = 8  # a mid-operator key group
    blob = eng.serialize(kg)
    before = dict(eng.store.get(kg))
    dst = (eng.router.node_of(kg) + 1) % eng.num_nodes
    eng.redirect(kg, dst)
    eng.install(kg, dst, blob)
    assert eng.store.get(kg) == before
    _feed_pipeline(eng, [50])
    eng._jit.sync_store()
    after = eng.store.get(kg)
    assert after.get("n", 0) >= before.get("n", 0)


# ---------------------------------------------------------------------------
# shard_map execution
# ---------------------------------------------------------------------------


def test_shard_map_single_device_parity():
    """With a 1-device mesh the run-sharded execution must be bit-identical
    to the plain jitted call (integer pipeline state and outputs)."""
    jax = pytest.importorskip("jax")
    mesh = jax.make_mesh((1,), ("nodes",), devices=jax.devices()[:1])
    sizes = [60, 130, 90]
    plain = Engine(
        make_pipeline_topo(8), 2, service_rate=1e9, seed=0, config=ExecutionConfig.jit()
    )
    sharded = Engine(
        make_pipeline_topo(8),
        2,
        service_rate=1e9,
        seed=0,
        config=ExecutionConfig.jit(mesh=mesh),
    )
    _feed_pipeline(plain, sizes)
    _feed_pipeline(sharded, sizes)
    assert sharded.metrics.jit_calls > 0
    assert plain.metrics.sink_outputs == sharded.metrics.sink_outputs
    plain._jit.sync_store()
    sharded._jit.sync_store()
    for kg in range(24):
        assert plain.store.get(kg) == sharded.store.get(kg)


SHARDED_PARITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax
    from repro.data.jobs import real_job_2
    from repro.data.synthetic import StreamSpec, airline_stream
    from repro.engine import Engine, ExecutionConfig

    mesh = jax.make_mesh((2,), ("nodes",), devices=jax.devices()[:2])
    kw = dict(service_rate=1e9, seed=0, collect_sinks=True)
    engines = [
        Engine(real_job_2(keygroups_per_op=4), 2,
               config=ExecutionConfig.jit(), **kw),
        Engine(real_job_2(keygroups_per_op=4), 2,
               config=ExecutionConfig.jit(mesh=mesh), **kw),
    ]
    stream = airline_stream(StreamSpec(rate=120.0, seed=5))
    batches = [next(stream) for _ in range(5)]
    for eng in engines:
        for k, v, ts in batches:
            eng.push_source("airline", k, v, ts)
            eng.tick()
        for _ in range(4):
            eng.tick()
        eng.end_period()
    a, b = engines
    assert b.metrics.jit_calls > 0
    assert a.metrics.processed_tuples == b.metrics.processed_tuples
    assert len(a.metrics.sink_outputs) == len(b.metrics.sink_outputs)
    for (k1, v1, t1), (k2, v2, t2) in zip(
        a.metrics.sink_outputs, b.metrics.sink_outputs
    ):
        assert k1 == k2 and t1 == t2
        np.testing.assert_allclose(v1[1], v2[1], rtol=1e-9, atol=1e-9)
    for kg in range(a.topology.num_keygroups):
        sa, sb = a.store.get(kg), b.store.get(kg)
        assert list(sa) == list(sb)
        for name in sa:
            assert list(sa[name]) == list(sb[name])
            np.testing.assert_allclose(
                list(sa[name].values()),
                list(sb[name].values()),
                rtol=1e-9,
                atol=1e-9,
            )

    # Duplicate key groups in one call (budget-leftover + fresh segments of
    # the same operator) must not shard-split: two shards updating the same
    # key group from the same base would double-count on merge.  The runtime
    # falls back to the plain call there — scalar state stays bit-exact.
    import jax.numpy as jnp
    from repro.engine.topology import (
        OperatorSpec, Schema, StateField, StateSchema, Topology
    )

    def mid_fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys, values, ts)

    def mid_jit(state, kgs, starts, ends, keys, values, ts):
        from repro.engine import jitexec as jx
        return (
            {"n": jx.count_runs(state["n"], kgs, starts, ends)},
            (keys, values, ts),
            None,
        )

    def scalar_topo():
        scalar = Schema(np.dtype(np.float64))
        t = Topology()
        t.add_operator(OperatorSpec(
            "src", None, num_keygroups=4, is_source=True, schema=scalar))
        t.add_operator(OperatorSpec(
            "mid", mid_fn, num_keygroups=4, is_sink=True, fn_jit=mid_jit,
            state_schema=StateSchema(
                (StateField("n", "scalar", dtype=np.int64, py=int),)
            ),
            schema=scalar, out_schema=scalar))
        t.connect("src", "mid")
        return t

    keys4 = np.arange(4, dtype=np.int64)
    vals4 = np.ones(4)
    ts4 = np.zeros(4)
    results = []
    for m in (None, mesh):
        e = Engine(scalar_topo(), 2, service_rate=1e9, seed=0,
                   config=ExecutionConfig.jit(mesh=m))
        g = e.topology.kg_base(1)
        out, lens = e._jit_exec(
            1, [g + 1, g + 1], [0, 2], [2, 4], keys4, vals4, ts4
        )
        e._jit.sync_store()
        results.append((e.store.get(g + 1), np.asarray(out[0]).tolist()))
    assert results[0] == results[1] == ({"n": 4}, [0, 1, 2, 3]), results
    print("SHARDED-PARITY-OK")
    """
)


def test_shard_map_two_device_parity():
    """Two forced host devices: run-sharded keyed-table execution merges
    per-shard state/output deltas into the same result as the plain call.
    Subprocess: the device count must be forced before any jax import."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_PARITY],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED-PARITY-OK" in proc.stdout

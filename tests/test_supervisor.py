"""Self-healing cluster: supervised respawn, periodic checkpoints, chaos.

The contract under test (docs/fault_tolerance.md): with a checkpoint
cadence and supervision configured, a worker SIGKILLed mid-stream is
respawned and the cluster rewinds to the latest checkpoint **without any
test-driven intervention** — and everything after the recovery's sink mark
is bit-identical to a fresh single-process engine restored from the same
checkpoint and fed the same post-checkpoint admissions.
"""

import os
import time

import numpy as np
import pytest

from conformance import make_pipeline_topo
from repro.engine import Engine, ExecutionConfig, make_engine
from repro.engine import checkpointing
from repro.engine.checkpointing import (
    payload_from_tree,
    restore_engine,
    snapshot_payload,
)
from repro.checkpoint.checkpoint import CheckpointManager
from repro.engine.cluster import WorkerPool
from repro.engine.config import CheckpointPolicy, SupervisionPolicy
from repro.engine.faults import FaultEvent, FaultPlan

KGS = 8
NODES = 4
TICKS_PER_PERIOD = 6


def _batches(n, size=200, key_space=5_000, seed=123):
    rng = np.random.default_rng(seed)
    return [
        (
            rng.integers(0, key_space, size=size).astype(np.int64),
            rng.random(size),
            np.full(size, float(t)),
        )
        for t in range(n)
    ]


def _healing_config(tmp_path, *, shm, every=2, supervision=None):
    return ExecutionConfig.workers(
        2,
        shm=shm,
        checkpoint=CheckpointPolicy(directory=str(tmp_path / "ck"), every=every),
        supervision=supervision or SupervisionPolicy(),
    )


def _drive_periods(eng, batches, periods):
    it = iter(batches)
    for _ in range(periods):
        for _ in range(TICKS_PER_PERIOD):
            keys, values, ts = next(it)
            eng.push_source("src", keys, values, ts)
            eng.tick()
        eng.end_period()


def _drain(eng, max_ticks=60):
    for _ in range(max_ticks):
        if eng.worst_queue_cost() == 0.0:
            return
        eng.tick()
    raise AssertionError("cluster failed to quiesce")


def _drain_oracle(eng, max_ticks=60):
    for _ in range(max_ticks):
        if not any(q.cost for q in eng._queues):
            return
        eng.tick()
    raise AssertionError("oracle failed to quiesce")


def _nonempty_states(store):
    return {kg: s for kg, s in store.items() if s}


@pytest.mark.parametrize("shm", [1 << 20, 0], ids=["shm", "queue"])
def test_auto_respawn_converges_to_oracle_replay(tmp_path, shm):
    """The acceptance scenario: SIGKILL one worker mid-stream, recover
    unattended, and match the oracle replayed from the surviving checkpoint.
    """
    kill_tick = 2 * TICKS_PER_PERIOD + 3  # mid period 3; checkpoint at p2
    batches = _batches(4 * TICKS_PER_PERIOD)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=_healing_config(tmp_path, shm=shm),
        service_rate=1e9,
        seed=0,
        faults=FaultPlan.of([FaultEvent("kill", 1, at_tick=kill_tick)]),
    )
    try:
        _drive_periods(cluster, batches, 4)
        _drain(cluster)
        cluster.finalize()
    finally:
        cluster.close()
    assert not any(p.is_alive() for p in cluster.pool.processes)

    assert len(cluster.recoveries) == 1
    report = cluster.recoveries[0]
    assert report.cause == "kill" and not report.gave_up
    assert report.worker == 1 and report.respawn_attempt == 1
    # The rewind target is the period-2 checkpoint: 12 ticks, 12 admissions.
    assert report.restored_step == 2 * TICKS_PER_PERIOD
    assert report.restored_cursor == 2 * TICKS_PER_PERIOD
    # Admissions 13..16 were buffered past the cut and replayed.
    assert report.replayed_batches == kill_tick + 1 - report.restored_cursor
    assert report.orphans > 0

    # Oracle: a fresh single-process engine restored from the *same*
    # checkpoint the cluster rewound to, with the cluster's post-recovery
    # allocation mirrored, fed every admission after the cut.
    tree, meta = CheckpointManager(str(tmp_path / "ck")).restore(
        step=report.restored_step
    )
    payload = payload_from_tree(tree)
    assert meta["ingest_cursor"] == report.restored_cursor
    payload["table"] = np.asarray(cluster.router.table, dtype=np.int64).copy()
    oracle = Engine(
        make_pipeline_topo(KGS),
        NODES,
        config=ExecutionConfig.typed(),
        service_rate=1e9,
        seed=0,
    )
    restore_engine(oracle, payload)
    for keys, values, ts in batches[report.restored_cursor :]:
        oracle.push_source("src", keys, values, ts)
        oracle.tick()
    _drain_oracle(oracle)

    # Everything after the recovery's sink mark is the oracle's output,
    # byte for byte; final states agree exactly.
    assert (
        cluster.metrics.sink_outputs[report.restored_sink_len :]
        == oracle.metrics.sink_outputs
    )
    assert _nonempty_states(cluster.store) == _nonempty_states(oracle.store)


def _merge_counts(a, b):
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + v
    return out


def _count_op(state, keys, values, ts):
    for k in keys.tolist():
        state[k] = state.get(k, 0) + 1
    return state, (keys, values, ts)


def _record_sink(state, keys, values, ts):
    state["n"] = state.get("n", 0) + len(keys)
    return state, (keys, values, ts)


def _make_split_topo(kgs=KGS):
    from repro.engine import OperatorSpec, Topology

    t = Topology()
    t.add_operator(
        OperatorSpec("src", None, num_keygroups=kgs, is_source=True)
    )
    t.add_operator(
        OperatorSpec(
            "count", _count_op, num_keygroups=kgs, merge_state=_merge_counts
        )
    )
    t.add_operator(
        OperatorSpec("sink", _record_sink, num_keygroups=kgs, is_sink=True)
    )
    t.connect("src", "count")
    t.connect("count", "sink")
    return t


def test_split_replicas_recover_through_checkpoint_path(tmp_path):
    """Replica (split) key groups ride the same checkpoint/restore path:
    split topology and round-robin fan-out cursors restore bit-exact, and
    the restored engine replayed over the post-cut admissions converges to
    the original run's tail."""
    cfg = ExecutionConfig.split(2, reserve=4)
    batches = _batches(18, key_space=40)  # narrow keys: every kg gets state

    def build():
        return Engine(
            _make_split_topo(),
            NODES,
            config=cfg,
            service_rate=1e9,
            seed=0,
        )

    eng = build()
    hot = KGS  # first key group of the "count" operator
    eng.split_keygroup(hot)
    assert eng.split_families()[hot]
    for keys, values, ts in batches[:12]:
        eng.push_source("src", keys, values, ts)
        eng.tick()
    _drain_oracle(eng)  # quiesce: queued-at-cut tuples are the loss bound
    payload = snapshot_payload(eng)
    sink_mark = payload["sink_len"]
    assert payload["split"]["map"] and payload["ingest_cursor"] == 12
    for keys, values, ts in batches[12:]:
        eng.push_source("src", keys, values, ts)
        eng.tick()
    _drain_oracle(eng)

    restored = build()
    restored.split_keygroup(hot)  # diverge the cursors before the restore
    restored.unsplit_keygroup(hot)
    restore_engine(restored, payload)
    assert restored._split_map == {
        int(p): list(f) for p, f in payload["split"]["map"].items()
    }
    assert restored._split_rr == {
        int(p): int(c) for p, c in payload["split"]["rr"].items()
    }
    assert restored.ingest_cursor == 12
    for keys, values, ts in batches[12:]:
        restored.push_source("src", keys, values, ts)
        restored.tick()
    _drain_oracle(restored)

    assert (
        eng.metrics.sink_outputs[sink_mark:] == restored.metrics.sink_outputs
    )
    assert _nonempty_states(eng.store) == _nonempty_states(restored.store)


def test_hung_worker_is_escalated_and_recovered(tmp_path):
    """Wedged ≠ dead: a worker stuck mid-command past the liveness deadline
    is SIGKILLed by the supervisor and recovered like a crash — the hang
    never runs to completion (recovery beats the 30 s wedge)."""
    # Deadline 1.5 s: far under the 30 s hang, far over any legitimate
    # pause on a loaded CI host (spurious escalation is the failure mode
    # the deadline knob exists for).
    supervision = SupervisionPolicy(hb_interval_s=0.25, hb_misses=6)
    batches = _batches(3 * TICKS_PER_PERIOD)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=_healing_config(tmp_path, shm=0, every=1, supervision=supervision),
        service_rate=1e9,
        seed=0,
        faults=FaultPlan.of(
            [FaultEvent("hang", 1, at_tick=TICKS_PER_PERIOD + 2, seconds=30.0)]
        ),
    )
    start = time.monotonic()
    try:
        _drive_periods(cluster, batches, 3)
        _drain(cluster)
        cluster.finalize()
    finally:
        cluster.close()
    assert time.monotonic() - start < 25.0
    assert [r.cause for r in cluster.recoveries] == ["hang"], cluster.recoveries
    assert not cluster.recoveries[0].gave_up
    assert len(cluster.metrics.sink_outputs) > 0


def test_shutdown_escalates_to_sigkill_on_ignoring_worker(monkeypatch):
    """Satellite regression: close() must terminate → kill on join timeout
    and leak no processes, even against a worker that ignores SIGTERM and
    never services another command."""
    monkeypatch.setattr(WorkerPool, "_GRACE_S", 0.5)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=ExecutionConfig.workers(2),
        service_rate=1e9,
        seed=0,
        timeout=1.0,  # the stop-ack wait gives up fast
    )
    batches = _batches(1)
    cluster.push_source("src", *batches[0])
    cluster.tick()
    # Wedge worker 1 in a SIGTERM-ignoring busy-hang, then shut down.
    cluster.pool.send(1, ("fault", "hang", 60.0, True))
    time.sleep(0.3)  # let it enter the hang (and install SIG_IGN)
    procs = list(cluster.pool.processes)
    cluster.close()
    assert not any(p.is_alive() for p in procs)


def test_counters_conserved_across_respawn(tmp_path):
    """Satellite: a kill at a just-checkpointed period boundary loses and
    duplicates nothing — finalize totals and exchange stats match the
    fault-free run exactly (the dead worker's last heartbeat is folded
    exactly once, the replacement counts from zero).  Each period drains
    before its boundary so the cut is quiesced — tuples queued at a cut
    are the loss bound, not a counting error."""
    batches = _batches(4 * TICKS_PER_PERIOD)

    def run(faults, sub):
        eng = make_engine(
            make_pipeline_topo(KGS),
            NODES,
            config=_healing_config(
                tmp_path / sub,
                shm=1 << 20,
                # keep: a re-homed table permutes sink order between the two
                # runs; pinning placement makes the comparison byte-exact.
                supervision=SupervisionPolicy(rehome="keep"),
            ),
            service_rate=1e9,
            seed=0,
            faults=faults,
        )
        it = iter(batches)
        try:
            for _ in range(4):
                for _ in range(TICKS_PER_PERIOD):
                    keys, values, ts = next(it)
                    eng.push_source("src", keys, values, ts)
                    eng.tick()
                _drain(eng)
                eng.end_period()
            eng.finalize()
        finally:
            eng.close()
        return eng

    plain = run(None, "a")
    healed = run(FaultPlan.kill_at_period(1, 2), "b")
    assert len(healed.recoveries) == 1
    assert healed.recoveries[0].replayed_batches == 0  # cut == crash point

    assert healed.metrics.sink_outputs == plain.metrics.sink_outputs
    for f in ("processed_tuples", "emitted_tuples", "sink_tuples", "ticks"):
        assert getattr(healed.metrics, f) == getattr(plain.metrics, f), f
    for f in ("shm_msgs", "queue_msgs"):
        if f in plain.exchange_stats:
            assert healed.exchange_stats[f] == plain.exchange_stats[f], f
    assert _nonempty_states(healed.store) == _nonempty_states(plain.store)


def _chaos_seeds():
    env = os.environ.get("CHAOS_SEEDS")
    return [int(s) for s in env.split(",")] if env else [0, 1, 2]


@pytest.mark.parametrize("seed", _chaos_seeds())
def test_seeded_chaos_run_is_bounded_and_leak_free(tmp_path, seed):
    """The 25-run fault soak as a chaos *suite*: a seeded FaultPlan drives
    kills/hangs/delays through a supervised cluster; the run must complete,
    recover every kill, and leak neither processes nor shm segments."""
    periods = 3
    plan = FaultPlan.random(
        seed, num_workers=2, periods=periods, hang_seconds=0.3
    )
    supervision = SupervisionPolicy(
        hb_interval_s=0.1, hb_misses=8, max_respawns=5
    )
    batches = _batches(periods * TICKS_PER_PERIOD, size=100)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=_healing_config(tmp_path, shm=1 << 20, every=1, supervision=supervision),
        service_rate=1e9,
        seed=seed,
        faults=plan,
    )
    try:
        _drive_periods(cluster, batches, periods)
        _drain(cluster)
        cluster.finalize()
    finally:
        cluster.close()
    assert not any(p.is_alive() for p in cluster.pool.processes)
    kills = sum(1 for e in plan.events if e.kind == "kill")
    recovered = sum(1 for r in cluster.recoveries if not r.gave_up)
    assert recovered >= min(kills, 1)
    assert len(cluster.metrics.sink_outputs) > 0
    if os.path.isdir("/dev/shm"):
        from repro.engine.shmx import SEGMENT_PREFIX

        assert not [
            f for f in os.listdir("/dev/shm") if f.startswith(SEGMENT_PREFIX)
        ]


def test_recovery_without_checkpoint_rewinds_to_start(tmp_path):
    """With supervision but no committed checkpoint yet, recovery rewinds
    to T0 and replays every buffered admission — slower, still converging."""
    batches = _batches(TICKS_PER_PERIOD)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=_healing_config(tmp_path, shm=0, every=50),
        service_rate=1e9,
        seed=0,
        faults=FaultPlan.of([FaultEvent("kill", 0, at_tick=3)]),
    )
    try:
        _drive_periods(cluster, batches, 1)
        _drain(cluster)
        cluster.finalize()
    finally:
        cluster.close()
    report = cluster.recoveries[0]
    assert report.restored_step == -1 and report.restored_cursor == 0
    assert report.replayed_batches == 4  # admissions 1..4 re-shipped

    oracle = Engine(
        make_pipeline_topo(KGS),
        NODES,
        config=ExecutionConfig.typed(),
        service_rate=1e9,
        seed=0,
    )
    # Mirror the re-homed allocation, then replay the whole feed.
    oracle.router.reset(np.asarray(cluster.router.table, dtype=np.int64))
    for keys, values, ts in batches:
        oracle.push_source("src", keys, values, ts)
        oracle.tick()
    _drain_oracle(oracle)
    assert (
        cluster.metrics.sink_outputs[report.restored_sink_len :]
        == oracle.metrics.sink_outputs
    )
    assert _nonempty_states(cluster.store) == _nonempty_states(oracle.store)


def test_respawn_budget_exhaustion_degrades_to_fail_node(tmp_path):
    """A kill beyond ``max_respawns`` is reported as gave_up and the worker
    stays dead — plain fail_node semantics, survivors keep serving."""
    supervision = SupervisionPolicy(max_respawns=0)
    batches = _batches(2 * TICKS_PER_PERIOD)
    cluster = make_engine(
        make_pipeline_topo(KGS),
        NODES,
        config=_healing_config(tmp_path, shm=0, supervision=supervision),
        service_rate=1e9,
        seed=0,
        faults=FaultPlan.of([FaultEvent("kill", 1, at_tick=3)]),
    )
    try:
        _drive_periods(cluster, batches, 2)
        _drain(cluster)
        cluster.finalize()
    finally:
        cluster.close()
    assert [r.gave_up for r in cluster.recoveries] == [True]
    assert 1 in cluster._dead_workers
    assert len(cluster.metrics.sink_outputs) > 0

"""End-to-end behaviour tests for the paper's system: the headline claims
reproduced in miniature, plus Algorithm-1 integration semantics."""

import numpy as np

from repro.core import (
    AdaptationFramework,
    AlbicParams,
    UtilizationScaler,
    solve_allocation,
)
from repro.core.baselines import flux_rebalance
from repro.data import airline_stream, real_job_2, real_job_3, real_job_4
from repro.data.synthetic import StreamSpec, weather_stream
from repro.engine import Controller, ControllerConfig, Engine

from conftest import make_cluster


def test_claim_milp_load_distance_beats_flux_over_time():
    """§5.2.1: MILP holds a stable low load distance where Flux fluctuates."""
    rng = np.random.default_rng(0)
    milp_ld, flux_ld = [], []
    milp_state = make_cluster(num_nodes=10, kgs_per_op=25, num_ops=4, seed=0)
    flux_state = milp_state.copy()
    for t in range(8):
        # Workload drift each period.
        drift = rng.uniform(0.9, 1.1, milp_state.num_keygroups)
        for st_ in (milp_state, flux_state):
            st_.kg_load = st_.kg_load * drift
        # 4s budget: at 2s the incumbent quality depended on host speed and
        # the claim flaked on slow machines; with headroom the MILP converges
        # well past Flux every period (ld ~0.4 vs ~1.5 on this workload).
        p = solve_allocation(milp_state, max_migrations=13, time_limit=4.0)
        milp_state.alloc = p.alloc
        milp_ld.append(milp_state.load_distance())
        f = flux_rebalance(flux_state, max_migrations=13)
        flux_state.alloc = f.alloc
        flux_ld.append(flux_state.load_distance())
    assert np.mean(milp_ld[2:]) <= np.mean(flux_ld[2:]) + 1e-9
    assert np.max(milp_ld[2:]) <= np.max(flux_ld[2:]) + 1e-9


def test_claim_albic_halves_load_index_on_real_job_2():
    """§5.4 Fig. 12: collocation cuts system load substantially."""
    topo = real_job_2(keygroups_per_op=24)
    g = topo.num_keygroups
    n = 6
    alloc = np.zeros(g, dtype=np.int64)
    alloc[:24] = np.arange(24) % n
    alloc[24:48] = np.arange(24) % n
    alloc[48:] = (np.arange(24) + n // 2) % n  # anti-collocated start
    eng = Engine(topo, n, initial_alloc=alloc, ser_cost=0.75, service_rate=2000.0)
    stream = airline_stream(StreamSpec(rate=250.0, seed=5))

    def feeder(engine, tick):
        keys, values, ts = next(stream)
        engine.push_source("airline", keys, values, ts)

    ctl = Controller(
        eng,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=15.0, time_limit=2.0),
        ),
        ControllerConfig(ticks_per_period=10),
        feeder=feeder,
    )
    for _ in range(10):
        m = ctl.period()
    assert m.load_index < 75.0, f"load index only reached {m.load_index:.1f}"
    assert m.collocation_factor > 80.0


def test_claim_job3_collocation_limited_by_routedelay():
    """§5.4 Fig. 13: RouteDelay partitions by a different key, capping the
    obtainable collocation below job 2's."""
    results = {}
    for job_fn, name in ((real_job_2, "job2"), (real_job_3, "job3")):
        topo = job_fn(keygroups_per_op=16)
        eng = Engine(topo, 4, ser_cost=0.5, service_rate=2000.0, seed=1)
        stream = airline_stream(StreamSpec(rate=200.0, seed=6))

        def feeder(engine, tick, stream=stream):
            keys, values, ts = next(stream)
            engine.push_source("airline", keys, values, ts)

        ctl = Controller(
            eng,
            AdaptationFramework(
                mode="albic",
                max_migrations=10,
                albic_params=AlbicParams(max_ld=20.0, time_limit=1.5),
            ),
            ControllerConfig(ticks_per_period=8),
            feeder=feeder,
        )
        for _ in range(8):
            m = ctl.period()
        results[name] = m.collocation_factor
    assert results["job3"] < results["job2"] - 5.0


def test_real_job_4_runs_and_improves():
    """The full enrichment pipeline (weather join) executes and adapts."""
    topo = real_job_4(keygroups_per_op=10)
    eng = Engine(topo, 4, ser_cost=0.5, service_rate=3000.0, seed=2)
    air = airline_stream(StreamSpec(rate=150.0, seed=7))
    wx = weather_stream(StreamSpec(rate=60.0, seed=7))

    def feeder(engine, tick):
        k, v, ts = next(air)
        engine.push_source("airline", k, v, ts)
        k, v, ts = next(wx)
        engine.push_source("weather", k, v, ts)

    ctl = Controller(
        eng,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=20.0, time_limit=1.5),
        ),
        ControllerConfig(ticks_per_period=8),
        feeder=feeder,
    )
    first = ctl.period()
    for _ in range(6):
        last = ctl.period()
    assert eng.metrics.processed_tuples > 2000
    assert last.collocation_factor >= first.collocation_factor
    # The join actually joined: efficiency buckets accumulated delay sums.
    bucket_state = [s for _, s in eng.store.items() if s.get("buckets")]
    assert bucket_state, "courier-efficiency operator never produced state"


def test_integration_scaling_sees_the_plan():
    """§4.1: overload fixable by re-balancing must NOT trigger scale-out."""
    state = make_cluster(num_nodes=6, kgs_per_op=20, num_ops=2, seed=9, skew=True)
    # Average load is low; only the skewed node is hot.
    state.kg_load = state.kg_load * (30.0 / max(state.node_loads().mean(), 1e-9) / 6)
    scaler = UtilizationScaler(high_wm=80.0, low_wm=5.0, target=50.0)
    fw = AdaptationFramework(
        scaler=scaler,
        mode="milp",
        max_migr_cost=1e9,
        time_limit=2.0,
    )
    result = fw.adapt(state)
    assert result.scaling.add_nodes == 0, "scaled out despite balanceable load"
    assert result.plan.load_distance < state.load_distance()


def test_scale_in_drains_and_terminates():
    """Marked nodes drain over periods and are terminated when empty."""
    state = make_cluster(num_nodes=6, kgs_per_op=10, num_ops=2, seed=11, skew=False)
    state.kill[5] = True
    fw = AdaptationFramework(mode="milp", max_migr_cost=40.0, time_limit=2.0)
    terminated = []
    for _ in range(25):
        result = fw.adapt(state)
        state = result.state
        terminated.extend(result.terminated)
        if 5 in terminated:
            break
    assert 5 in terminated, "node 5 never drained+terminated"
    assert (state.alloc != 5).all()

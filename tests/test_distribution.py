"""Sharding rules, roofline parsing, and a reduced-mesh dry-run subprocess."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    CollectiveStats,
    analyze_hlo,
    model_flops_estimate,
    parse_collectives,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# rules resolution (no devices needed — use a fake mesh view)
# ---------------------------------------------------------------------------


class FakeMesh:
    def __init__(self, **axes):
        self.shape = axes
        self.axis_names = tuple(axes)


def test_rules_divisibility():
    from repro.launch.sharding import rules_for

    mesh = FakeMesh(data=16, model=16)
    cfg = get_config("llama3_2_3b")  # 24 heads — not divisible by 16
    rules = rules_for(cfg, SHAPES["train_4k"], mesh)
    assert rules["heads"] is None
    assert rules["ff"] == "model"  # 8192 % 16 == 0
    assert rules["batch"] == ("data",)

    cfg2 = get_config("glm4_9b")  # 32 heads — divisible
    rules2 = rules_for(cfg2, SHAPES["train_4k"], mesh)
    assert rules2["heads"] == "model"


def test_rules_decode_cache():
    from repro.launch.sharding import rules_for

    mesh = FakeMesh(data=16, model=16)
    glm = get_config("glm4_9b")  # kv=2 → sequence-sharded cache
    r = rules_for(glm, SHAPES["decode_32k"], mesh)
    assert r["cache_heads"] is None and r["cache_seq"] == "model"
    gem = get_config("gemma_7b")  # kv=16 → head-sharded cache
    r2 = rules_for(gem, SHAPES["decode_32k"], mesh)
    assert r2["cache_heads"] == "model"


def test_rules_degenerate_batch():
    from repro.launch.sharding import rules_for

    mesh = FakeMesh(data=16, model=16)
    cfg = get_config("recurrentgemma_2b")
    rules = rules_for(cfg, SHAPES["long_500k"], mesh)  # batch 1
    assert rules["batch"] is None


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = textwrap.dedent(
    """
    HloModule jit_step

    %body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %lhs = f32[8,16]{1,0} parameter(1)
      %rhs = f32[16,8]{1,0} parameter(2)
      %dot.1 = f32[8,8]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %all-reduce.1 = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={{0,1,2,3}}, to_apply=%add
    }

    %cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
      %c = s32[] constant(12)
      ROOT %cmp = pred[] compare(%c, %c), direction=LT
    }

    ENTRY %main (a: f32[8,16], b: f32[16,8]) -> f32[8,8] {
      %a = f32[8,16]{1,0} parameter(0)
      %b = f32[16,8]{1,0} parameter(1)
      %ag = f32[32,16]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
      %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
    }
    """
)


def test_parse_collectives_trip_weighting():
    stats = parse_collectives(SAMPLE_HLO)
    assert stats.counts["all-reduce"] == 1
    assert stats.counts["all-gather"] == 1
    # all-reduce inside the while body: 8·8·4 B × 2·(3/4) ring × 12 trips.
    ar = stats.wire_bytes["all-reduce"]
    assert abs(ar - (8 * 8 * 4) * 2 * 0.75 * 12) < 1e-6
    # all-gather in entry: result 32·16·4 × 3/4, once.
    ag = stats.wire_bytes["all-gather"]
    assert abs(ag - (32 * 16 * 4) * 0.75) < 1e-6


def test_analyze_hlo_flops_trip_weighting():
    a = analyze_hlo(SAMPLE_HLO)
    # dot inside the while body: 2·8·8·16 × 12 trips.
    assert abs(a.flops - 2 * 8 * 8 * 16 * 12) < 1e-6
    assert a.num_dots == 1
    assert a.hbm_bytes > 0


def test_model_flops_estimates():
    cfg = get_config("glm4_9b")
    train = model_flops_estimate(cfg, SHAPES["train_4k"])
    assert abs(train - 6 * cfg.param_count() * 4096 * 256) / train < 1e-6
    moe = get_config("dbrx_132b")
    t2 = model_flops_estimate(moe, SHAPES["train_4k"])
    assert t2 < 6 * moe.param_count() * 4096 * 256  # active < total


# ---------------------------------------------------------------------------
# reduced-mesh dry run (subprocess: needs its own XLA_FLAGS)
# ---------------------------------------------------------------------------

SMALL_DRYRUN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, dataclasses
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import SHAPES, get_config
    from repro.launch import sharding as shd
    from repro.launch.roofline import analyze_hlo
    from repro.models import make_train_step
    from repro.models.common import activation_rules
    from repro.optim import AdamW

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("dbrx_132b", smoke=True)
    cfg = dataclasses.replace(cfg, vocab_size=512, cycles=2)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=4)
    rules = shd.rules_for(cfg, shape, mesh)
    opt = AdamW()
    with mesh, activation_rules(rules, mesh=mesh):
        p_shapes = shd.param_shapes(cfg)
        p_shard = shd.param_shardings(cfg, mesh, rules)
        o_shapes = shd.opt_shapes(cfg, opt)
        o_shard = shd.opt_shardings(cfg, mesh, rules)
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
        }
        b_shard = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        rep = NamedSharding(mesh, P())
        step = make_train_step(cfg, opt)
        lowered = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, {"loss": rep, "grad_norm": rep}),
        ).lower(p_shapes, o_shapes, batch)
        compiled = lowered.compile()
        a = analyze_hlo(compiled.as_text())
        assert a.flops > 0, "no dot flops found"
        mem = compiled.memory_analysis()
        print("OK", a.flops, int(a.hbm_bytes), len(a.collectives.counts))
    """
)


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SMALL_DRYRUN],
        capture_output=True,
        text=True,
        timeout=420,
        env=env,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.startswith("OK")

"""Per-architecture smoke tests (reduced configs): forward/train/decode on
CPU, shape and finiteness assertions, prefill↔decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs, shape_applicable
from repro.models import Model, init_params, make_serve_step
from repro.models.kvcache import init_cache
from repro.optim import AdamW
from repro.models.transformer import make_train_step


def smoke_batch(cfg, b=2, s=64):
    if cfg.is_encdec:
        return {
            "encoder_embeds": jnp.full((b, s, cfg.d_model), 0.01, jnp.bfloat16),
            "tokens": jnp.ones((b, 16), jnp.int32),
            "labels": jnp.ones((b, 16), jnp.int32),
        }
    if cfg.decoder_only_inputs_embeds:
        return {
            "inputs_embeds": jnp.full((b, s, cfg.d_model), 0.01, jnp.bfloat16),
            "labels": jnp.ones((b, s), jnp.int32),
        }
    return {
        "tokens": jnp.ones((b, s), jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = Model(cfg)
    batch = smoke_batch(cfg)
    logits, _, _ = model.forward(
        params,
        tokens=batch.get("tokens"),
        inputs_embeds=batch.get("inputs_embeds"),
        encoder_embeds=batch.get("encoder_embeds"),
    )
    expect_s = batch["labels"].shape[1]
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"

    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # Parameters actually changed.
    def absmax(a, b):
        return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())

    delta = jax.tree.map(absmax, params, p2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    b = 2
    cache = init_cache(cfg, b, 64, enc_len=32 if cfg.is_encdec else 0)
    serve = jax.jit(make_serve_step(cfg))
    logits, cache = serve(
        params, cache, jnp.ones((b, 1), jnp.int32), jnp.zeros((b,), jnp.int32)
    )
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # A second step at the next position also works (cache round-trips).
    logits2, _ = serve(
        params, cache, jnp.ones((b, 1), jnp.int32), jnp.ones((b,), jnp.int32)
    )
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["glm4_9b", "llama3_2_3b", "gemma_7b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode over a prefix must match the prefill logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(2))
    model = Model(cfg)
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    full_logits, cache, _ = model.forward(
        params, tokens=tokens, build_cache=True, cache_capacity=s + 8
    )

    # Decode token s (feeding tokens[s-1] is already in cache; feed a new one).
    nxt = jnp.full((b, 1), 7, jnp.int32)
    dec_logits, _ = model.decode_step(
        params, cache, nxt, jnp.full((b,), s, jnp.int32)
    )
    # Reference: full forward over the extended sequence.
    ext = jnp.concatenate([tokens, nxt], axis=1)
    ref_logits, _, _ = model.forward(params, tokens=ext)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(ref_logits[:, -1], np.float32),
        atol=0.75,  # bf16 params + different contraction orders
        rtol=0.15,
    )
    # And the argmax agrees (the decision that matters for decoding).
    assert int(dec_logits[:, 0].argmax()) == int(ref_logits[:, -1].argmax())


def test_long_500k_applicability_matches_design():
    runs = {
        a for a in ARCH_IDS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]
    }
    assert runs == {"recurrentgemma_2b", "xlstm_1_3b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        assert specs, f"{arch}×{name} produced no input specs"
        for sd in specs.values():
            assert all(d > 0 for d in sd.shape)


def test_param_counts_match_published_sizes():
    # Sanity anchors: |published size − computed| within 15%.
    anchors = {
        "glm4_9b": 9.4e9,
        "llama3_2_3b": 3.2e9,
        "mistral_nemo_12b": 12.2e9,
        "dbrx_132b": 132e9,
        "recurrentgemma_2b": 2.7e9,
        "whisper_small": 0.24e9,
        "qwen2_vl_7b": 7.6e9,
    }
    for arch, target in anchors.items():
        got = get_config(arch).param_count()
        assert abs(got - target) / target < 0.20, f"{arch}: {got:.3g} vs {target:.3g}"

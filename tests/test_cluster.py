"""Multi-worker host runtime: determinism, seeding, pipelined ingestion,
envelope export/import and elastic growth across live worker processes.

The conformance matrix (tests/conformance.py) already pins the 2-worker
configuration against the single-process oracle on every real job; this
suite covers what the matrix can't — uneven 3-worker splits, seed
reproducibility, the pipelined ``run_stream`` mode, the public envelope
API, and the coordinator's elastic/lifecycle surface.
"""

import numpy as np

from conformance import (
    Scenario,
    _pipeline_feeders,
    assert_equivalent,
    make_pipeline_topo,
    run_scenario,
)
from repro.engine import Engine, ExecutionConfig, make_engine
from repro.engine.cluster import (
    ClusterEngine,
    contiguous_node_worker,
    worker_rng,
)

KGS = 8


def _cluster(num_workers=2, num_nodes=4, service_rate=1e9, seed=0, **kw):
    return make_engine(
        make_pipeline_topo(KGS),
        num_nodes,
        config=ExecutionConfig.workers(num_workers),
        service_rate=service_rate,
        seed=seed,
        **kw,
    )


def _push(eng, n, seed, key_space=5_000):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, size=n).astype(np.int64)
    return eng.push_source("src", keys, rng.random(n), np.zeros(n))


def _drain(eng, max_ticks=60):
    for _ in range(max_ticks):
        if eng.worst_queue_cost() == 0.0:
            return
        eng.tick()
    raise AssertionError("cluster failed to quiesce")


def test_contiguous_node_worker_is_monotone_and_balanced():
    for n, w in [(4, 2), (5, 2), (4, 3), (7, 3), (2, 2)]:
        owners = contiguous_node_worker(n, w)
        assert (np.diff(owners) >= 0).all()  # the determinism contract
        counts = np.bincount(owners, minlength=w)
        assert counts.min() >= 1 and counts.max() - counts.min() <= 1


def test_three_workers_uneven_split_matches_oracle():
    # 4 nodes over 3 workers → blocks of size 2/1/1: the uneven-split case
    # the 2-worker conformance matrix never exercises.
    scenario = Scenario("uneven", ticks=10, drain_ticks=8, migrate_at=(3, 6))
    results = {
        config.name: run_scenario(
            make_pipeline_topo, _pipeline_feeders, scenario, config
        )
        for config in (ExecutionConfig.typed(), ExecutionConfig.workers(3))
    }
    assert_equivalent(results)
    assert results["soa+seg+schema+workers"]["migration_blobs"]


def test_same_seed_reproduces_run_exactly():
    def drive(seed):
        with _cluster(seed=seed) as eng:
            alloc = eng.router.table.copy()
            for t in range(5):
                _push(eng, 200, seed=100 + t)
                eng.tick()
            _drain(eng)
            eng.finalize()
            return alloc, eng.metrics.sink_outputs, eng.metrics.sink_tuples

    a0, s0, n0 = drive(seed=7)
    a1, s1, n1 = drive(seed=7)
    assert np.array_equal(a0, a1) and s0 == s1 and n0 == n1
    a2, _, _ = drive(seed=8)
    assert not np.array_equal(a0, a2)  # seed reaches the alloc draw


def test_worker_rng_streams_are_deterministic_and_distinct():
    assert np.array_equal(
        worker_rng(3, 0).random(4), worker_rng(3, 0).random(4)
    )
    assert not np.array_equal(
        worker_rng(3, 0).random(4), worker_rng(3, 1).random(4)
    )
    assert not np.array_equal(
        worker_rng(3, 0).random(4), worker_rng(4, 0).random(4)
    )


def _batches(n_batches, size=150, seed=11, key_space=5_000):
    rng = np.random.default_rng(seed)
    return [
        (
            rng.integers(0, key_space, size=size).astype(np.int64),
            rng.random(size),
            np.full(size, float(t)),
        )
        for t in range(n_batches)
    ]


def test_run_stream_matches_lockstep_ticks():
    batches = _batches(10)
    with _cluster() as piped:
        accepted_p = piped.run_stream("src", batches, window=4)
        _drain(piped)
        piped.finalize()
    with _cluster() as lock:
        accepted_l = 0
        for keys, values, ts in batches:
            accepted_l += lock.push_source("src", keys, values, ts)
            lock.tick()
        _drain(lock)
        lock.finalize()
    assert accepted_p == accepted_l == sum(len(b[0]) for b in batches)
    assert piped.metrics.sink_outputs == lock.metrics.sink_outputs
    assert piped.metrics.sink_tuples == lock.metrics.sink_tuples
    assert [s for _, s in piped.store.items()] == [
        s for _, s in lock.store.items()
    ]


def test_run_stream_backpressure_conserves_tuples():
    # A tight service budget forces the asynchronous credit loop to drop
    # tuples at the source; whatever was accepted must reach the sink.
    batches = _batches(12, size=1000)
    with _cluster(service_rate=50.0) as eng:
        accepted = eng.run_stream("src", batches, window=3)
        _drain(eng, max_ticks=400)
        eng.finalize()
    assert 0 < accepted < sum(len(b[0]) for b in batches)
    assert eng.metrics.dropped_credits == sum(len(b[0]) for b in batches) - accepted
    assert eng.metrics.sink_tuples == accepted


def test_run_stream_shuffle_is_seed_reproducible():
    batches = _batches(8)

    def drive(seed):
        with _cluster(seed=seed) as eng:
            accepted = eng.run_stream("src", batches, shuffle=True)
            _drain(eng)
            eng.finalize()
            return accepted, eng.metrics.sink_outputs

    acc0, sinks0 = drive(seed=5)
    acc1, sinks1 = drive(seed=5)
    assert acc0 == acc1 == sum(len(b[0]) for b in batches)
    assert sinks0 == sinks1


def test_export_envelope_identical_to_single_process():
    single = Engine(
        make_pipeline_topo(KGS),
        4,
        config=ExecutionConfig.typed(),
        service_rate=1e9,
        seed=0,
    )
    with _cluster() as cluster:
        assert np.array_equal(single.router.table, cluster.router.table)
        for t in range(4):
            _push(single, 200, seed=40 + t)
            _push(cluster, 200, seed=40 + t)
            single.tick()
            cluster.tick()
        base = single.topology.kg_base(1)
        for kg in range(base, base + KGS):
            env_s = single.export_keygroup(kg)
            env_c = cluster.export_keygroup(kg)
            assert env_c.version == env_s.version == 1
            assert env_c.keygroup == kg
            assert env_c.blob == env_s.blob  # byte-identical envelope


def test_import_keygroup_installs_across_workers():
    with _cluster() as eng:
        for t in range(4):
            _push(eng, 200, seed=60 + t)
            eng.tick()
        _drain(eng)
        base = eng.topology.kg_base(1)
        # Pick a key group and move it to a node on the *other* worker.
        kg = next(
            k for k in range(base, base + KGS)
            if eng.worker_of_node(eng.router.node_of(k)) == 0
        )
        dst = int(np.flatnonzero(eng.node_worker == 1)[0])
        env = eng.export_keygroup(kg)
        eng.import_keygroup(env, dst)
        assert eng.router.node_of(kg) == dst
        accepted2 = _push(eng, 200, seed=99)
        _drain(eng)
        eng.finalize()
    expected = 4 * 200 + accepted2
    assert eng.metrics.sink_tuples == expected
    assert sum(
        eng.store.get(k).get("n", 0) for k in range(base, base + KGS)
    ) == expected


def test_add_nodes_stays_monotone_and_carries_traffic():
    with _cluster() as eng:
        accepted = _push(eng, 200, seed=1)
        _drain(eng)
        eng.add_nodes(2)
        assert eng.num_nodes == 6
        assert (np.diff(eng.node_worker) >= 0).all()
        assert (eng.node_worker[-2:] == eng.num_workers - 1).all()
        # Migrate a key group onto a fresh node and keep the job flowing.
        base = eng.topology.kg_base(1)
        eng.redirect(base, 5)
        eng.install(base, 5, eng.serialize(base))
        accepted2 = _push(eng, 200, seed=2)
        _drain(eng)
        eng.finalize()
    assert eng.metrics.sink_tuples == accepted + accepted2


def test_close_terminates_worker_processes():
    eng = _cluster()
    procs = list(eng.pool.processes)
    assert all(p.is_alive() for p in procs)
    _push(eng, 100, seed=3)
    eng.tick()
    eng.close()
    for p in procs:
        p.join(timeout=10)
    assert not any(p.is_alive() for p in procs)
    eng.close()  # idempotent


# ---------------------------------------------------------------------------
# Shared-memory exchange lanes: transport selection and overflow fallback
# ---------------------------------------------------------------------------


def _run_matched(shm, ticks=6, n=300):
    """Drive identical traffic through a 3-worker cluster with the given
    ring size and the single-process oracle; return both engines."""
    cluster = make_engine(
        make_pipeline_topo(KGS),
        4,
        config=ExecutionConfig.workers(3, shm=shm),
        service_rate=1e9,
        seed=0,
    )
    oracle = make_engine(
        make_pipeline_topo(KGS),
        4,
        config=ExecutionConfig.typed(),
        service_rate=1e9,
        seed=0,
    )
    try:
        for t in range(ticks):
            rng = np.random.default_rng(70 + t)
            keys = rng.integers(0, 5_000, size=n).astype(np.int64)
            values, ts = rng.random(n), np.zeros(n)
            cluster.push_source("src", keys, values, ts)
            oracle.push_source("src", keys, values, ts)
            cluster.tick()
            oracle.tick()
        for _ in range(60):
            if cluster.worst_queue_cost() == 0.0 and not any(
                q.cost for q in oracle._queues
            ):
                break
            cluster.tick()
            oracle.tick()
        cluster.finalize()
    finally:
        cluster.close()
    return cluster, oracle


def _assert_matches_oracle(cluster, oracle):
    assert cluster.metrics.sink_outputs == oracle.metrics.sink_outputs
    c_states = {kg: s for kg, s in cluster.store.items() if s}
    o_states = {kg: s for kg, s in oracle.store.items() if s}
    assert c_states == o_states


def test_shm_lanes_carry_the_exchange_bit_exact():
    cluster, oracle = _run_matched(shm=1 << 20)
    _assert_matches_oracle(cluster, oracle)
    xs = cluster.exchange_stats
    assert xs["shm_msgs"] > 0 and xs["queue_msgs"] == 0
    assert xs["shm_bytes_out"] > 0 and xs["shm_bytes_in"] > 0


def test_ring_full_overflow_falls_back_bit_exact():
    # A 128-byte ring holds an empty record but no real batch: every
    # payload-carrying message must overflow to the queue path, mixing
    # transports per (tick, lane) — the merge must not notice.
    cluster, oracle = _run_matched(shm=128)
    _assert_matches_oracle(cluster, oracle)
    xs = cluster.exchange_stats
    assert xs["shm_msgs"] > 0 and xs["queue_msgs"] > 0


def test_queue_only_transport_stays_bit_exact():
    cluster, oracle = _run_matched(shm=0)
    _assert_matches_oracle(cluster, oracle)
    xs = cluster.exchange_stats
    assert xs["shm_msgs"] == 0 and xs["queue_msgs"] > 0
    assert xs["shm_bytes_out"] == 0

"""Shared fixtures.  NB: no XLA_FLAGS here — tests must see 1 real device;
only launch/dryrun.py forces 512 placeholder devices."""

import numpy as np
import pytest

from repro.core.stats import ClusterState


def make_cluster(
    num_nodes: int = 8,
    kgs_per_op: int = 20,
    num_ops: int = 4,
    *,
    seed: int = 0,
    one_to_one_frac: float = 0.5,
    skew: bool = True,
) -> ClusterState:
    """Synthetic cluster in the style of the paper's §5.1 setup."""
    rng = np.random.default_rng(seed)
    g = kgs_per_op * num_ops
    kg_op = np.repeat(np.arange(num_ops), kgs_per_op)
    load = rng.uniform(0.5, 2.0, g)
    alloc = rng.integers(0, num_nodes, g)
    if skew:
        alloc[: g // 4] = 0  # overload node 0
    out = np.zeros((g, g))
    n11 = int(kgs_per_op * one_to_one_frac)
    for op in range(num_ops - 1):
        base, nxt = op * kgs_per_op, (op + 1) * kgs_per_op
        for i in range(n11):  # one-to-one pattern — collocatable
            out[base + i, nxt + i] = rng.uniform(5, 15)
        for i in range(n11, kgs_per_op):  # full partitioning — even fan-out
            out[base + i, nxt : nxt + kgs_per_op] = rng.uniform(0.05, 0.15, kgs_per_op)
    downstream = {i: [i + 1] for i in range(num_ops - 1)}
    downstream[num_ops - 1] = []
    return ClusterState.create(
        num_nodes,
        kg_op,
        load,
        alloc,
        kg_state_bytes=rng.uniform(1, 10, g),
        out_rates=out,
        downstream=downstream,
    )


@pytest.fixture
def cluster() -> ClusterState:
    return make_cluster()

"""The real jobs' fn_seg ports (and their schema-typed columnar edges) must
be bit-identical to the per-run fn, and the SoA queue to the deque oracle,
under every drive pattern.

Each test runs one job through the six execution configurations
(soa+seg+schema+jit+superstep, soa+seg+schema+jit, soa+seg+schema, soa+seg,
soa+fn, deque+fn — see tests/conformance.py) and requires identical tuple
flow, sink outputs,
per-key-group state and SPL statistics (the jit configuration with the
documented float tolerance on reduction-order-sensitive running sums):

* ``steady``   — unconstrained budgets, pure data-plane equivalence;
* ``migrate``  — three random mid-run migrations: tuples buffered in flight,
  queue extraction rebuilds segments non-contiguous, fn_seg must fall back
  to fn without diverging;
* ``pressure`` — a binding service budget (partial drains, cursor
  resumption, mixed seg/fn interleavings) plus one migration.
"""

import numpy as np
import pytest

from conformance import JOBS, Scenario, assert_equivalent, run_configs

SCENARIOS = {
    "steady": Scenario("steady"),
    "migrate": Scenario("migrate", migrate_at=(3, 6, 9)),
    "pressure": Scenario("pressure", service_rate=260.0, migrate_at=(5,), ticks=16),
}


# Jobs with fn_jit-ported operators (job4 extends job3, so it inherits the
# ported flight-delay operators): the +jit configuration must actually
# exercise the compiled tier there (and never anywhere else).
JIT_JOBS = {"job2", "job3", "job4", "pipeline"}


@pytest.mark.parametrize("scenario", list(SCENARIOS), ids=str)
@pytest.mark.parametrize("job", list(JOBS), ids=str)
def test_job_conformance(job, scenario):
    topo_factory, feeder_factory = JOBS[job]
    results = run_configs(topo_factory, feeder_factory, SCENARIOS[scenario])
    assert_equivalent(results)
    # The production configuration actually exercised the vectorized path
    # and routed schema-typed batches; the oracle configurations stayed on
    # per-run fn / object arrays (equivalence over nothing is vacuous).
    assert results["soa+seg+schema"]["seg_calls"] > 0
    assert results["soa+seg+schema"]["typed_batches"] > 0
    assert results["soa+seg"]["seg_calls"] > 0
    assert results["soa+seg"]["typed_batches"] == 0
    assert results["soa+fn"]["seg_calls"] == 0
    assert results["deque+fn"]["seg_calls"] == 0
    assert results["deque+fn"]["typed_batches"] == 0
    assert results["soa+seg+schema"]["metrics"]["processed_tuples"] > 0
    # Compiled tier: fires exactly on the +jit configuration of ported jobs,
    # with compile counts bounded by padding buckets, not calls.
    jit = results["soa+seg+schema+jit"]
    if job in JIT_JOBS:
        assert jit["jit_calls"] > 0
        assert 0 < jit["jit_compiles"] < jit["jit_calls"]
    else:
        assert jit["jit_calls"] == 0
    assert results["soa+seg+schema"]["jit_calls"] == 0
    assert results["deque+fn"]["jit_calls"] == 0


def test_jobs_produce_sink_output_and_state():
    """The conformance drive is not vacuous: sinks emit and state accretes."""
    for job, (topo_factory, feeder_factory) in JOBS.items():
        res = run_configs(topo_factory, feeder_factory, SCENARIOS["steady"])
        seg = res["soa+seg+schema"]
        assert seg["metrics"]["sink_tuples"] > 0, job
        non_empty = sum(1 for s in seg["states"] if s != ("dict", []))
        assert non_empty > 0, job


def test_migration_actually_interleaved():
    """The migrate scenario really moves key groups mid-run (allocation
    differs from the initial random table) on every configuration."""
    topo_factory, feeder_factory = JOBS["job2"]
    plain = run_configs(topo_factory, feeder_factory, SCENARIOS["steady"])
    moved = run_configs(topo_factory, feeder_factory, SCENARIOS["migrate"])
    assert_equivalent(moved)
    assert moved["soa+seg+schema"]["alloc"] != plain["soa+seg+schema"]["alloc"]


def test_pressure_scenario_is_binding():
    """The backpressure scenario leaves a different drain trajectory than the
    steady one — the budget was really binding somewhere."""
    topo_factory, feeder_factory = JOBS["job4"]
    steady = run_configs(topo_factory, feeder_factory, SCENARIOS["steady"])
    pressed = run_configs(topo_factory, feeder_factory, SCENARIOS["pressure"])
    assert_equivalent(pressed)
    # Same total work eventually drains, but the per-tick interleaving (and
    # hence the number of whole-segment fn_seg calls) must differ.
    seg = "soa+seg+schema"
    assert pressed[seg]["seg_calls"] != steady[seg]["seg_calls"]


def test_normalize_pins_dict_insertion_order():
    """The harness' state comparison is order-sensitive: two dicts with equal
    items in different insertion order are different states (tie-breaks and
    pickle bytes depend on it)."""
    from conformance import normalize

    assert normalize({"a": 1, "b": 2}) != normalize({"b": 2, "a": 1})
    assert normalize({"a": np.int64(1)}) == normalize({"a": 1})

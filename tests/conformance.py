"""Differential conformance harness for engine data-plane equivalence.

One scenario — a topology, randomized sources, optional migrations and
backpressure — is driven through every execution configuration:

* ``soa+seg+schema``     — SoA work queues, segment-vectorized ``fn_seg``,
  declared schemas honored (columnar structured-array edges);
* ``soa+seg+schema+jit`` — same plus the compiled tier: operators declaring
  ``fn_jit`` execute contiguous segments as jitted programs over device
  state columns (``repro.engine.jitexec``); operators without ``fn_jit``
  fall back bit-identically to the numpy ``fn_seg``;
* ``soa+seg+schema+jit+superstep`` — same plus ``Engine(superstep=True)``:
  eligible whole ticks fuse route → drain → ``fn_jit`` into one device
  program (``repro.engine.superstep``), falling back to the classic tick —
  after materializing device-pending columns — whenever a tick is not
  fusible, so every pinned field (including migration blobs) must still
  match;
* ``soa+seg``   — schemas stripped (``use_schema=False``): every edge
  carries the object-array representation;
* ``soa+fn``    — SoA queues with ``fn_seg`` also stripped (every run takes
  the per-run ``fn``);
* ``deque+fn``  — the legacy per-entry deque queue (always per-run ``fn``),
  the original oracle;
* ``soa+seg+schema+workers`` — the multi-worker host runtime
  (``ExecutionConfig.workers(2)``): the same topology sharded over two real
  OS worker processes (:class:`repro.engine.cluster.ClusterEngine`), nodes
  assigned in contiguous ascending blocks.  Because the exchange merges
  each operator's cross-worker contributions in ascending worker order —
  which equals the single-process node-ascending flush order under
  contiguous blocks — this configuration is pinned **bit-identical** in
  every tuple-carrying field: queues, states, sink values *and order*,
  credits, routing and migration envelope bytes (no sink order
  normalization is needed while the node → worker map stays monotone).
  The one relaxation is float *statistics summation*: the coordinator
  folds per-worker partial sums of the SPL usage windows, so key groups
  with non-dyadic per-tuple costs may differ from the oracle's single
  running sum by a few ulp — ``kg_load`` and ``pair_rate`` are compared
  with :data:`WORKERS_FLOAT_RTOL` (everything integer-derived stays
  exact).  See docs/execution_tiers.md for the full contract.

The run results must be *bit-identical*: every tuple-flow metric, the sink
outputs (values and order), every key group's operator state (including dict
insertion order — it decides TopK tie-breaks and pickle bytes), the folded
SPL statistics (loads, arrival rates, sparse pair rates, state sizes), the
routing table, the per-node queue costs, and the migration envelope bytes
(hashed per install — the proof that a cross-worker serialize → install
round trip ships exactly the single-process blob).  Envelope bytes encode
backlog batches in the configuration's own edge encoding, so they are
pinned only across configurations sharing the base's schema encoding — the
``+workers`` comparison that matters; schema-stripped configs pickle
object-array backlogs and are exempt from that one field.

One documented escape hatch: the jit configuration's *multi-term float
reductions* (running sums via ``jnp.cumsum``) may diverge from the oracle's
strict left-to-right association in the last bits, because XLA's scan uses
a different reduction order.  ``assert_equivalent`` therefore compares the
``+jit`` configuration's ``sink_outputs`` and ``states`` with
:data:`JIT_FLOAT_RTOL`/:data:`JIT_FLOAT_ATOL` on floats — structure, ints,
strings, ordering and every other pinned field stay exact (integer tuple
flow must never inherit the tolerance: jit operators' float outputs must
not feed partition keys, see docs/operator_authoring.md).

This is the required check for new operators, new ``fn_seg`` ports and new
schema declarations: add a topology + feeder entry to ``JOBS`` (or call
:func:`run_configs` directly) and assert with :func:`assert_equivalent`.
See ``tests/test_real_jobs_conformance.py`` for the real-job instantiation
and ``docs/operator_authoring.md`` for the authoring contract.

:func:`make_fuzz_topology` extends the harness with *randomized* topologies
— random fan-out DAGs, key dtypes, schema/no-schema mixes over a library of
generic operators — driven by hypothesis in
``tests/test_conformance_fuzz.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro.data.jobs import make_real_job_1, real_job_2, real_job_3, real_job_4
from repro.data.synthetic import (
    StreamSpec,
    airline_stream,
    weather_stream,
    wiki_edit_stream,
)
from repro.engine import ExecutionConfig, make_engine
from repro.engine.topology import (
    OperatorSpec,
    Schema,
    StateField,
    StateSchema,
    Topology,
)

# The full configuration matrix, keyed by ExecutionConfig.name.  The workers
# configuration sits before the jit ones so its processes fork before any
# jax state exists in this process.
CONFIGS = tuple(
    (c.name, c)
    for c in (
        ExecutionConfig.typed(),
        ExecutionConfig.seg(),
        ExecutionConfig(use_fn_seg=False, use_schema=False),
        ExecutionConfig.oracle(),
        ExecutionConfig.workers(2),
        ExecutionConfig.jit(),
        ExecutionConfig.superstep(),
    )
)

# The hypothesis fuzz suites draw dozens of examples; they skip the workers
# configuration (process pool per example) to stay fast — the fixed jobs
# and the cluster suite pin it.
FUZZ_CONFIGS = tuple(
    (name, c) for name, c in CONFIGS if c.num_workers == 1
)

# The documented XLA reduction-order tolerance (see module docstring): only
# the ``+jit`` configuration's floats are compared with it, and only in the
# ``sink_outputs``/``states`` fields — everything else stays bit-exact.
JIT_FLOAT_RTOL = 1e-9
JIT_FLOAT_ATOL = 1e-9
_TOLERANT_FIELDS = ("sink_outputs", "states")

# The workers configuration's documented statistics relaxation (see module
# docstring): per-worker partial sums vs the oracle's single running sum —
# a few ulp on non-dyadic cost charges, nothing more.
WORKERS_FLOAT_RTOL = 1e-12
WORKERS_FLOAT_ATOL = 1e-18
_WORKERS_TOLERANT_FIELDS = ("kg_load", "pair_rate")

METRIC_FIELDS = (
    "processed_tuples",
    "emitted_tuples",
    "sink_tuples",
    "cross_node_tuples",
    "intra_node_tuples",
    "dropped_credits",
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One randomized drive of a topology, identical across configurations."""

    name: str
    ticks: int = 14
    drain_ticks: int = 8
    service_rate: float = 1e9
    num_nodes: int = 4
    seed: int = 0
    # Ticks at which a random key group is redirected; its state is installed
    # at the destination one tick later (traffic in between exercises the
    # router's in-flight buffering and the non-contiguous fn fallback).
    migrate_at: tuple[int, ...] = ()


def normalize(obj):
    """Recursively convert to comparable plain structures.

    Dicts become ordered item lists — insertion order is part of the
    conformance contract (it decides stable-sort tie-breaks and pickle
    bytes, hence migration blobs and ``kg_state_bytes``).
    """
    if isinstance(obj, dict):
        return ("dict", [(normalize(k), normalize(v)) for k, v in obj.items()])
    if isinstance(obj, (list, tuple)):
        return ("seq", [normalize(x) for x in obj])
    if isinstance(obj, np.ndarray):
        return ("array", obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def run_scenario(topo_factory, feeder_factory, scenario, config):
    """Drive one :class:`ExecutionConfig` through the scenario; return a
    result dict of everything the equivalence contract pins."""
    topo = topo_factory()
    eng = make_engine(
        topo,
        scenario.num_nodes,
        config=config,
        service_rate=scenario.service_rate,
        seed=scenario.seed,
    )
    feeds = feeder_factory()
    rng = np.random.default_rng(scenario.seed + 1)
    in_flight: list[tuple[int, int, int]] = []
    migration_blobs: list[str] = []
    for t in range(scenario.ticks):
        if t in scenario.migrate_at:
            # Drawn unconditionally so the rng stream (and therefore every
            # subsequent choice) is identical across configurations.
            kg = int(rng.integers(0, topo.num_keygroups))
            dst = int(rng.integers(0, eng.num_nodes))
            if not eng.router.is_in_flight(kg):
                eng.redirect(kg, dst)
                in_flight.append((t, kg, dst))
        for op, it in feeds.items():
            keys, values, ts = next(it)
            eng.push_source(op, keys, values, ts)
        eng.tick()
        for item in list(in_flight):
            t0, kg, dst = item
            if t >= t0 + 1:
                blob = eng.serialize(kg)
                migration_blobs.append(hashlib.sha256(blob).hexdigest())
                eng.install(kg, dst, blob)
                in_flight.remove(item)
    for _ in range(scenario.drain_ticks):
        eng.tick()
    snap = eng.end_period()
    eng.finalize()  # multi-worker: gather states/metrics, stop the pool
    return {
        "metrics": {m: getattr(eng.metrics, m) for m in METRIC_FIELDS},
        "sink_outputs": normalize(eng.metrics.sink_outputs),
        "states": [normalize(s) for _, s in eng.store.items()],
        "kg_load": snap.kg_load.tolist(),
        "kg_tuple_rate": snap.kg_tuple_rate.tolist(),
        "kg_state_bytes": snap.kg_state_bytes.tolist(),
        "pair_src": snap.out_pairs.src.tolist(),
        "pair_dst": snap.out_pairs.dst.tolist(),
        "pair_rate": snap.out_pairs.rate.tolist(),
        "alloc": eng.router.table.tolist(),
        "queue_costs": eng.queue_costs(),
        "migration_blobs": migration_blobs,
        "seg_calls": eng.metrics.seg_calls,
        "seg_tuples": eng.metrics.seg_tuples,
        "typed_batches": eng.metrics.typed_batches,
        "jit_calls": eng.metrics.jit_calls,
        "jit_compiles": eng.metrics.jit_compiles,
        "jit_host_syncs": eng.metrics.jit_host_syncs,
    }


def run_configs(topo_factory, feeder_factory, scenario, configs=CONFIGS):
    """Run every execution configuration; returns {config name: result}."""
    return {
        name: run_scenario(topo_factory, feeder_factory, scenario, config)
        for name, config in configs
    }


def approx_equal(a, b, rtol: float, atol: float) -> bool:
    """Structural equality over normalized results with float tolerance.

    Structure, ints, bools and strings must match exactly (bool/int/float
    type flips count as differences); only float *values* may differ within
    the tolerance — the shape of the documented XLA reduction-order escape
    hatch.
    """
    if type(a) is not type(b):
        return False
    if isinstance(a, float):
        return a == b or math.isclose(a, b, rel_tol=rtol, abs_tol=atol)
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(
            approx_equal(x, y, rtol, atol) for x, y in zip(a, b)
        )
    return a == b


def assert_equivalent(results: dict[str, dict]) -> None:
    """All configurations must agree on every pinned field, bit for bit —
    except the ``+jit`` configuration's float values in the tolerant fields
    (see module docstring)."""
    names = list(results)
    base_name, base = names[0], results[names[0]]
    for name in names[1:]:
        other = results[name]
        tol = "+jit" in name
        for field, expect in base.items():
            if field in (
                "seg_calls",
                "seg_tuples",
                "typed_batches",
                "jit_calls",
                "jit_compiles",
                "jit_host_syncs",
            ):
                continue  # differs by construction across configurations
            if field == "migration_blobs" and (tol or "schema" not in name):
                # Envelope byte equality is pinned between same-encoding
                # configurations only: schema-stripped configs legitimately
                # pickle object-array backlogs where typed configs ship raw
                # buffer slices, and the jit configurations' documented
                # float tolerance makes byte equality too strong.  The
                # claim that matters — a cross-worker migration envelope is
                # byte-identical to the single-process one — is exactly the
                # base vs ``+workers`` comparison, which stays exact.
                continue
            got = other[field]
            if "+workers" in name and field in _WORKERS_TOLERANT_FIELDS:
                assert approx_equal(
                    got, expect, WORKERS_FLOAT_RTOL, WORKERS_FLOAT_ATOL
                ), (
                    f"{base_name} vs {name}: {field} differs beyond the "
                    f"workers statistics-summation tolerance:"
                    f"\n  {str(expect)[:400]}\n  {str(got)[:400]}"
                )
                continue
            if field == "states":
                for kg, (a, b) in enumerate(zip(expect, got)):
                    same = (
                        approx_equal(a, b, JIT_FLOAT_RTOL, JIT_FLOAT_ATOL)
                        if tol
                        else a == b
                    )
                    assert same, (
                        f"{base_name} vs {name}: state of key group {kg} differs:"
                        f"\n  {a!r}\n  {b!r}"
                    )
                continue
            if tol and field in _TOLERANT_FIELDS:
                assert approx_equal(
                    got, expect, JIT_FLOAT_RTOL, JIT_FLOAT_ATOL
                ), (
                    f"{base_name} vs {name}: {field} differs beyond the "
                    f"jit float tolerance:"
                    f"\n  {str(expect)[:400]}\n  {str(got)[:400]}"
                )
                continue
            assert got == expect, (
                f"{base_name} vs {name}: {field} differs:"
                f"\n  {str(expect)[:400]}\n  {str(got)[:400]}"
            )


# ---------------------------------------------------------------------------
# Job registry: the four real jobs plus the synthetic pipeline.
# ---------------------------------------------------------------------------

_KGS = 12  # small key-group counts keep the suite fast but multi-run


def _wiki_feeders():
    return {"wiki": wiki_edit_stream(StreamSpec(rate=90.0, seed=5))}


def _airline_feeders():
    return {"airline": airline_stream(StreamSpec(rate=90.0, seed=5))}


def _job4_feeders():
    return {
        "airline": airline_stream(StreamSpec(rate=90.0, seed=5)),
        "weather": weather_stream(StreamSpec(rate=40.0, seed=5)),
    }


def _int_batches(rate=120, key_space=10_000, seed=5):
    rng = np.random.default_rng(seed)
    tick = 0
    while True:
        n = int(rng.poisson(rate))
        keys = rng.integers(0, key_space, size=n).astype(np.int64)
        yield keys, rng.random(n), np.full(n, float(tick))
        tick += 1


def _pipe_mid_jit(state, kgs, starts, ends, keys, values, ts):
    from repro.engine import jitexec as jx

    return (
        {"n": jx.count_runs(state["n"], kgs, starts, ends)},
        (keys + 17, values, ts),
        None,
    )


def _pipe_sink_jit(state, kgs, starts, ends, keys, values, ts):
    from repro.engine import jitexec as jx

    return (
        {"n": jx.count_runs(state["n"], kgs, starts, ends)},
        (keys * 2, values, ts),
        None,
    )


_PIPE_STATE = StateSchema((StateField("n", "scalar", dtype=np.int64, py=int),))


def make_pipeline_topo(kgs: int = 16) -> Topology:
    """The synthetic source → re-key → recording-sink pipeline, with all
    three operator protocols (shared with the migration property tests).
    Every edge declares the scalar float64 payload schema, so the same
    topology runs typed (native key/value dtypes end to end, raw-buffer
    migration blobs), untyped via ``Engine(use_schema=...)``, or compiled
    via ``Engine(use_fn_jit=True)`` (per-key-group counters in jit-tier
    scalar state columns)."""

    scalar = Schema(np.dtype(np.float64))

    def mid_fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys + 17, values, ts)

    def mid_seg(store, run_kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys + 17, values, ts), None

    def sink_fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys * 2, values, ts)

    def sink_seg(store, run_kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys * 2, values, ts), None

    t = Topology()
    t.add_operator(
        OperatorSpec("src", None, num_keygroups=kgs, is_source=True, schema=scalar)
    )
    t.add_operator(
        OperatorSpec(
            "mid",
            mid_fn,
            num_keygroups=kgs,
            fn_seg=mid_seg,
            fn_jit=_pipe_mid_jit,
            jit_fusible=True,
            state_schema=_PIPE_STATE,
            schema=scalar,
            out_schema=scalar,
        )
    )
    t.add_operator(
        OperatorSpec(
            "sink",
            sink_fn,
            num_keygroups=kgs,
            is_sink=True,
            fn_seg=sink_seg,
            fn_jit=_pipe_sink_jit,
            jit_fusible=True,
            state_schema=_PIPE_STATE,
            schema=scalar,
            out_schema=scalar,
        )
    )
    t.connect("src", "mid")
    t.connect("mid", "sink")
    return t


def _pipeline_feeders():
    return {"src": _int_batches()}


JOBS = {
    "job1": (
        lambda: make_real_job_1(keygroups_per_op=_KGS, topk=3, window_ticks=4.0),
        _wiki_feeders,
    ),
    "job2": (lambda: real_job_2(keygroups_per_op=_KGS), _airline_feeders),
    "job3": (lambda: real_job_3(keygroups_per_op=_KGS), _airline_feeders),
    "job4": (lambda: real_job_4(keygroups_per_op=_KGS), _job4_feeders),
    "pipeline": (lambda: make_pipeline_topo(_KGS), _pipeline_feeders),
}


# ---------------------------------------------------------------------------
# Fuzzing mode: randomized topologies over a library of generic operators.
#
# A *fuzz spec* is a plain dict (hypothesis draws it in
# tests/test_conformance_fuzz.py) describing a random fan-out DAG:
#
#   {"family": "scalar" | "record",       # value payload family
#    "key_dtype": "i8" | "i4",            # declared key dtype
#    "source_schema": bool,               # source edge declared?
#    "ops": [{"kind": ..., "kgs": int,    # per middle operator
#             "schema": bool,             # input edge declared?
#             "out_schema": bool,         # output edge declared?
#             "key": "id" | "mod" | "byval"},
#            ...],
#    "edges": [[upstream indices], ...]}  # -1 = source, else earlier op
#
# Every operator implements fn + fn_seg, and each fn_seg handles both value
# representations, so any schema/no-schema mix along any DAG must stay
# bit-identical across the full CONFIGS matrix.  All kinds except the
# keyed-table ``accum`` also carry an fn_jit port (attached whenever the
# declared schemas allow the jit tier to run them — see
# :func:`_fuzz_jit_bodies`), so the same DAGs exercise the compiled tier
# and, on eligible linear chains, the fused superstep.
# ---------------------------------------------------------------------------

FUZZ_RECORD_DTYPE = np.dtype([("a", "i8"), ("b", "f8")])
FUZZ_KINDS = {
    "scalar": ("rekey", "vshift", "filter", "window", "accum"),
    "record": ("rekey", "project", "filter", "window", "accum"),
}

# Sliding-count window length of the "window" fuzz operator.
_FUZZ_WINDOW = 5


def _count_runs(store, run_kgs, starts, ends):
    for kg, a, z in zip(run_kgs, starts, ends):
        st = store[kg]
        st["n"] = st.get("n", 0) + (z - a)


def _fuzz_stateful_bodies(kind: str, family: str):
    """Windowed / keyed-accumulator generic operators — the ROADMAP's
    "extend the fuzz pool toward windowed/stateful operators".

    ``window`` keeps a sliding count window (last :data:`_FUZZ_WINDOW`
    payloads) per key group and emits each tuple with its window sum;
    ``accum`` keeps a keyed accumulator (payloads summed by ``key % 7``)
    and emits the running totals.  Both walk tuples in order inside
    ``fn_seg`` — what these operators fuzz is *stateful* equivalence
    across representations, schema mixes and migrations, not
    vectorization — and the python ``sum``/left-fold keeps every float
    trajectory bit-identical to the per-run oracle.
    """
    rec = family == "record"

    def _payload(v):
        return v[1] if rec else v

    def _emit(v, s):
        return (v[0], s) if rec else s

    if kind == "window":

        def run(state, out, keys, values, ts):
            buf = state.setdefault("buf", [])
            vals = values.tolist() if isinstance(values, np.ndarray) else values
            for k, v, t in zip(keys.tolist(), vals, np.asarray(ts).tolist()):
                buf.append(_payload(v))
                if len(buf) > _FUZZ_WINDOW:
                    del buf[0]
                out.append((k, _emit(v, sum(buf)), t))

    else:  # accum

        def run(state, out, keys, values, ts):
            acc = state.setdefault("acc", {})
            vals = values.tolist() if isinstance(values, np.ndarray) else values
            for k, v, t in zip(keys.tolist(), vals, np.asarray(ts).tolist()):
                kk = k % 7
                s = acc.get(kk, 0.0) + _payload(v)
                acc[kk] = s
                out.append((k, _emit(v, s), t))

    def fn(state, keys, values, ts):
        out = []
        run(state, out, keys, values, ts)
        return state, out

    def seg(store, run_kgs, starts, ends, keys, values, ts):
        out = []
        lens = []
        for kg, a, z in zip(run_kgs, starts, ends):
            before = len(out)
            run(store[kg], out, keys[a:z], values[a:z], ts[a:z])
            lens.append(len(out) - before)
        if not out:
            return None, None
        ok, ov, ot = zip(*out)
        if rec:
            ov_arr = np.empty(len(ov), dtype=object)
            ov_arr[:] = list(ov)
        else:
            ov_arr = np.asarray(ov)
        return (np.asarray(ok), ov_arr, np.asarray(ot)), lens

    return fn, seg


def _fuzz_bodies(kind: str, family: str):
    """(fn, fn_seg) for one generic operator, bit-identical across
    representations (structured column views vs object tuples)."""
    if kind in ("window", "accum"):
        return _fuzz_stateful_bodies(kind, family)
    if family == "scalar":
        if kind == "rekey":

            def fn(state, keys, values, ts):
                state["n"] = state.get("n", 0) + len(keys)
                return state, (keys + 7, values, ts)

            def seg(store, run_kgs, starts, ends, keys, values, ts):
                _count_runs(store, run_kgs, starts, ends)
                return (keys + 7, values, ts), None

        elif kind == "vshift":

            def fn(state, keys, values, ts):
                state["n"] = state.get("n", 0) + len(keys)
                return state, (keys, values + 0.5, ts)

            def seg(store, run_kgs, starts, ends, keys, values, ts):
                _count_runs(store, run_kgs, starts, ends)
                return (keys, values + 0.5, ts), None

        else:  # filter

            def fn(state, keys, values, ts):
                state["n"] = state.get("n", 0) + len(keys)
                keep = keys % 3 != 0
                return state, (keys[keep], values[keep], ts[keep])

            def seg(store, run_kgs, starts, ends, keys, values, ts):
                _count_runs(store, run_kgs, starts, ends)
                keep = keys % 3 != 0
                lens = [int(keep[a:z].sum()) for a, z in zip(starts, ends)]
                return (keys[keep], values[keep], ts[keep]), lens

        return fn, seg

    # record family: values are (a: i8, b: f8) records
    def _project_cols(values):
        """(a column, b column) as native arrays, either representation."""
        if values.dtype.names is not None:
            return values["a"], values["b"]
        a_l, b_l = zip(*values.tolist())
        return np.asarray(a_l, dtype=np.int64), np.asarray(b_l)

    def _record_out(values, a, b):
        if values.dtype.names is not None:
            out = np.empty(len(a), dtype=FUZZ_RECORD_DTYPE)
            out["a"] = a
            out["b"] = b
            return out
        out = np.empty(len(a), dtype=object)
        out[:] = list(zip(a.tolist(), b.tolist()))
        return out

    if kind == "rekey":

        def fn(state, keys, values, ts):
            state["n"] = state.get("n", 0) + len(keys)
            return state, (keys + 7, values, ts)

        def seg(store, run_kgs, starts, ends, keys, values, ts):
            _count_runs(store, run_kgs, starts, ends)
            return (keys + 7, values, ts), None

    elif kind == "project":

        def fn(state, keys, values, ts):
            state["n"] = state.get("n", 0) + len(keys)
            out = [
                (k, (v[0], v[1] + v[0]), t)
                for k, v, t in zip(keys.tolist(), values.tolist(), ts.tolist())
            ]
            return state, out

        def seg(store, run_kgs, starts, ends, keys, values, ts):
            _count_runs(store, run_kgs, starts, ends)
            a, b = _project_cols(values)
            return (keys, _record_out(values, a, b + a), ts), None

    else:  # filter on the record's a field

        def fn(state, keys, values, ts):
            state["n"] = state.get("n", 0) + len(keys)
            a, _ = _project_cols(values)
            keep = a % 3 != 0
            return state, (keys[keep], values[keep], ts[keep])

        def seg(store, run_kgs, starts, ends, keys, values, ts):
            _count_runs(store, run_kgs, starts, ends)
            a, _ = _project_cols(values)
            keep = a % 3 != 0
            lens = [int(keep[a_:z].sum()) for a_, z in zip(starts, ends)]
            return (keys[keep], values[keep], ts[keep]), lens

    return fn, seg


_FUZZ_JIT_STATE = StateSchema(
    (StateField("n", "scalar", dtype=np.int64, py=int),)
)
_FUZZ_WINDOW_STATE = StateSchema(
    (
        StateField(
            "buf", "vector", dtype=np.float64, py=float, length=_FUZZ_WINDOW
        ),
    )
)


def _fuzz_jit_bodies(kind: str, family: str):
    """(fn_jit, state_schema) port of one generic fuzz operator.

    ``accum`` (keyed-table state) stays on the numpy tiers → ``(None,
    None)``.  The ports follow the fn_jit contract end to end: run bounds
    may be padded (``kgs`` with the key-group count, ``starts``/``ends``
    with the tuple count), scatters use ``mode="drop"``, and the 1:1 ops'
    state updates are run-order-insensitive scatter-adds.  ``filter``
    compacts with a stable partition (kept tuples keep the oracle's global
    order) and returns per-run ``out_counts``; ``window`` mirrors the
    oracle's left-fold window sum over a :class:`repro.engine.jitexec.
    VectorState` ring, so its floats stay bit-identical, not merely within
    the jit tolerance.
    """
    rec = family == "record"
    if kind == "accum":
        return None, None

    if kind == "rekey":

        def fn_jit(state, kgs, starts, ends, keys, values, ts):
            from repro.engine import jitexec as jx

            return (
                {"n": jx.count_runs(state["n"], kgs, starts, ends)},
                (keys + 7, values, ts),
                None,
            )

        return fn_jit, _FUZZ_JIT_STATE

    if kind == "vshift":

        def fn_jit(state, kgs, starts, ends, keys, values, ts):
            from repro.engine import jitexec as jx

            return (
                {"n": jx.count_runs(state["n"], kgs, starts, ends)},
                (keys, values + 0.5, ts),
                None,
            )

        return fn_jit, _FUZZ_JIT_STATE

    if kind == "project":

        def fn_jit(state, kgs, starts, ends, keys, values, ts):
            from repro.engine import jitexec as jx

            return (
                {"n": jx.count_runs(state["n"], kgs, starts, ends)},
                (keys, {"a": values["a"], "b": values["b"] + values["a"]}, ts),
                None,
            )

        return fn_jit, _FUZZ_JIT_STATE

    if kind == "filter":

        def fn_jit(state, kgs, starts, ends, keys, values, ts):
            import jax.numpy as jnp

            from repro.engine import jitexec as jx

            n = keys.shape[0]
            new = {"n": jx.count_runs(state["n"], kgs, starts, ends)}
            keep = (values["a"] % 3 != 0) if rec else (keys % 3 != 0)
            keepv = jx.tuple_valid(starts, ends, n) & keep
            # Stable partition: kept tuples first, in run-major order — the
            # compacted layout the engine splits back by out_counts.
            order = jnp.argsort(jnp.where(keepv, 0, 1), stable=True)
            if rec:
                ov = {nm: col[order] for nm, col in values.items()}
            else:
                ov = values[order]
            oc = (
                jnp.zeros(kgs.shape[0], jnp.int64)
                .at[jx.run_of_tuples(ends, n)]
                .add(keepv.astype(jnp.int64))
            )
            return new, (keys[order], ov, ts[order]), oc

        return fn_jit, _FUZZ_JIT_STATE

    # window: sliding count window over a fixed-length VectorState ring.
    def fn_jit(state, kgs, starts, ends, keys, values, ts):
        import jax.numpy as jnp

        from repro.engine import jitexec as jx

        W = _FUZZ_WINDOW
        data, cnt = state["buf"].data, state["buf"].cnt
        nkg = data.shape[0]
        n = keys.shape[0]
        payload = values["b"] if rec else values
        # Per-tuple window sum: tuple at position p (its run's m-th payload,
        # ring count c before the run) sums the last min(W, c+m) of
        # ring ++ payload[start..p], oldest first — the oracle's left fold.
        ridx = jx.run_of_tuples(ends, n)
        kg_t = jnp.clip(kgs[ridx], 0, nkg - 1)
        c_t = cnt[kg_t].astype(jnp.int64)
        pos = jnp.arange(n)
        m = pos - starts[ridx] + 1
        s = jnp.zeros(n, jnp.float64)
        for d in range(W - 1, -1, -1):  # back-offset from the newest element
            pay = payload[jnp.clip(pos - d, 0, n - 1)]
            ring = data[kg_t, jnp.clip(c_t + m - 1 - d, 0, W - 1)]
            s = jnp.where(d < c_t + m, s + jnp.where(d < m, pay, ring), s)
        # New ring per run: the last min(W, c+L) elements of ring ++ payload,
        # re-packed oldest-first into slots [0, new_cnt).
        L = ends - starts
        kg_r = jnp.clip(kgs, 0, nkg - 1)
        c_r = cnt[kg_r].astype(jnp.int64)
        new_cnt = jnp.minimum(c_r + L, W)
        j = jnp.arange(W)[None, :]
        s_idx = (c_r + L - new_cnt)[:, None] + j
        from_pay = s_idx >= c_r[:, None]
        pay_idx = starts[:, None] + (s_idx - c_r[:, None])
        row = jnp.where(
            j < new_cnt[:, None],
            jnp.where(
                from_pay,
                payload[jnp.clip(pay_idx, 0, n - 1)],
                data[kg_r[:, None], jnp.clip(s_idx, 0, W - 1)],
            ),
            0.0,
        )
        new_vst = jx.VectorState(
            data.at[kgs].set(row, mode="drop"),
            cnt.at[kgs].set(new_cnt.astype(cnt.dtype), mode="drop"),
        )
        out_v = {"a": values["a"], "b": s} if rec else s
        return {"buf": new_vst}, (keys, out_v, ts), None

    return fn_jit, _FUZZ_WINDOW_STATE


def make_fuzz_topology(spec: dict) -> Topology:
    """Build the randomized DAG a fuzz spec describes (deterministic)."""
    family = spec["family"]
    key_dtype = np.dtype(spec["key_dtype"])
    value_dtype = (
        FUZZ_RECORD_DTYPE if family == "record" else np.dtype(np.float64)
    )
    schema = Schema(value_dtype, key=key_dtype)
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "src",
            None,
            num_keygroups=spec.get("source_kgs", 8),
            is_source=True,
            schema=schema if spec["source_schema"] else None,
        )
    )
    for i, op in enumerate(spec["ops"]):
        fn, seg = _fuzz_bodies(op["kind"], family)
        kw = {}
        if op["key"] == "mod":
            kw["key_fn"] = lambda k: k % 13
        elif op["key"] == "byval" and family == "record":
            kw["key_by_value"] = lambda v: v[0] % 11
            kw["key_by_value_col"] = lambda v: v["a"] % np.int64(11)
        fj, st = _fuzz_jit_bodies(op["kind"], family)
        # The jit tier needs native input columns (declared input schema)
        # and, for record-family dict outputs, a declared out_schema to
        # assemble the structured output array.
        if fj is not None and op["schema"] and (
            family == "scalar" or op["out_schema"]
        ):
            kw["fn_jit"] = fj
            kw["state_schema"] = st
            # Fusible = strictly 1:1 with run-order-insensitive scalar
            # state and an unmapped partition key (superstep contract).
            kw["jit_fusible"] = (
                op["kind"] in ("rekey", "vshift", "project")
                and op["key"] == "id"
            )
        t.add_operator(
            OperatorSpec(
                f"op{i}",
                fn,
                num_keygroups=op["kgs"],
                fn_seg=seg,
                schema=schema if op["schema"] else None,
                out_schema=schema if op["out_schema"] else None,
                **kw,
            )
        )
    for i, ups in enumerate(spec["edges"]):
        for u in ups:
            t.connect("src" if u < 0 else f"op{u}", f"op{i}")
    return t


def fuzz_feeders(spec: dict, *, rate: float = 90.0, seed: int = 5):
    """Deterministic source feeders matching a fuzz spec's value family."""
    family = spec["family"]

    def factory():
        def gen():
            rng = np.random.default_rng(seed)
            tick = 0
            while True:
                n = int(rng.poisson(rate))
                keys = rng.integers(0, 100_000, size=n).astype(np.int64)
                if family == "record":
                    a = rng.integers(0, 1_000, size=n)
                    b = rng.random(n)
                    values = list(zip(a.tolist(), b.tolist()))
                else:
                    values = rng.random(n)
                yield keys, values, np.full(n, float(tick))
                tick += 1

        return {"src": gen()}

    return factory

"""Differential conformance harness for engine data-plane equivalence.

One scenario — a topology, randomized sources, optional migrations and
backpressure — is driven through every execution configuration:

* ``soa+seg``   — SoA work queues with the segment-vectorized ``fn_seg``
  protocol enabled (the production path);
* ``soa+fn``    — SoA queues with ``fn_seg`` stripped (every run takes the
  per-run ``fn``);
* ``deque+fn``  — the legacy per-entry deque queue (always per-run ``fn``),
  the original oracle.

The run results must be *bit-identical*: every tuple-flow metric, the sink
outputs (values and order), every key group's operator state (including dict
insertion order — it decides TopK tie-breaks and pickle bytes), the folded
SPL statistics (loads, arrival rates, sparse pair rates, state sizes), the
routing table and the per-node queue costs.

This is the required check for new operators and new ``fn_seg`` ports: add a
topology + feeder entry to ``JOBS`` (or call :func:`run_configs` directly)
and assert with :func:`assert_equivalent`.  See
``tests/test_real_jobs_conformance.py`` for the real-job instantiation and
``docs/operator_authoring.md`` for the authoring contract.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.jobs import make_real_job_1, real_job_2, real_job_3, real_job_4
from repro.data.synthetic import (
    StreamSpec,
    airline_stream,
    weather_stream,
    wiki_edit_stream,
)
from repro.engine import Engine
from repro.engine.topology import OperatorSpec, Topology

CONFIGS = (("soa", True), ("soa", False), ("deque", False))

METRIC_FIELDS = (
    "processed_tuples",
    "emitted_tuples",
    "sink_tuples",
    "cross_node_tuples",
    "intra_node_tuples",
    "dropped_credits",
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One randomized drive of a topology, identical across configurations."""

    name: str
    ticks: int = 14
    drain_ticks: int = 8
    service_rate: float = 1e9
    num_nodes: int = 4
    seed: int = 0
    # Ticks at which a random key group is redirected; its state is installed
    # at the destination one tick later (traffic in between exercises the
    # router's in-flight buffering and the non-contiguous fn fallback).
    migrate_at: tuple[int, ...] = ()


def normalize(obj):
    """Recursively convert to comparable plain structures.

    Dicts become ordered item lists — insertion order is part of the
    conformance contract (it decides stable-sort tie-breaks and pickle
    bytes, hence migration blobs and ``kg_state_bytes``).
    """
    if isinstance(obj, dict):
        return ("dict", [(normalize(k), normalize(v)) for k, v in obj.items()])
    if isinstance(obj, (list, tuple)):
        return ("seq", [normalize(x) for x in obj])
    if isinstance(obj, np.ndarray):
        return ("array", obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def run_scenario(topo_factory, feeder_factory, scenario, *, queue_impl, use_fn_seg):
    """Drive one engine configuration through the scenario; return a result
    dict of everything the equivalence contract pins."""
    topo = topo_factory()
    eng = Engine(
        topo,
        scenario.num_nodes,
        service_rate=scenario.service_rate,
        seed=scenario.seed,
        queue_impl=queue_impl,
        use_fn_seg=use_fn_seg,
    )
    feeds = feeder_factory()
    rng = np.random.default_rng(scenario.seed + 1)
    in_flight: list[tuple[int, int, int]] = []
    for t in range(scenario.ticks):
        if t in scenario.migrate_at:
            # Drawn unconditionally so the rng stream (and therefore every
            # subsequent choice) is identical across configurations.
            kg = int(rng.integers(0, topo.num_keygroups))
            dst = int(rng.integers(0, eng.num_nodes))
            if not eng.router.is_in_flight(kg):
                eng.redirect(kg, dst)
                in_flight.append((t, kg, dst))
        for op, it in feeds.items():
            keys, values, ts = next(it)
            eng.push_source(op, keys, values, ts)
        eng.tick()
        for item in list(in_flight):
            t0, kg, dst = item
            if t >= t0 + 1:
                eng.install(kg, dst, eng.serialize(kg))
                in_flight.remove(item)
    for _ in range(scenario.drain_ticks):
        eng.tick()
    snap = eng.end_period()
    return {
        "metrics": {m: getattr(eng.metrics, m) for m in METRIC_FIELDS},
        "sink_outputs": normalize(eng.metrics.sink_outputs),
        "states": [normalize(s) for _, s in eng.store.items()],
        "kg_load": snap.kg_load.tolist(),
        "kg_tuple_rate": snap.kg_tuple_rate.tolist(),
        "kg_state_bytes": snap.kg_state_bytes.tolist(),
        "pair_src": snap.out_pairs.src.tolist(),
        "pair_dst": snap.out_pairs.dst.tolist(),
        "pair_rate": snap.out_pairs.rate.tolist(),
        "alloc": eng.router.table.tolist(),
        "queue_costs": [q.cost for q in eng._queues],
        "seg_calls": eng.metrics.seg_calls,
        "seg_tuples": eng.metrics.seg_tuples,
    }


def run_configs(topo_factory, feeder_factory, scenario):
    """Run every execution configuration; returns {config name: result}."""
    return {
        f"{impl}+{'seg' if seg else 'fn'}": run_scenario(
            topo_factory, feeder_factory, scenario, queue_impl=impl, use_fn_seg=seg
        )
        for impl, seg in CONFIGS
    }


def assert_equivalent(results: dict[str, dict]) -> None:
    """All configurations must agree on every pinned field, bit for bit."""
    names = list(results)
    base_name, base = names[0], results[names[0]]
    for name in names[1:]:
        other = results[name]
        for field, expect in base.items():
            if field in ("seg_calls", "seg_tuples"):
                continue  # differs by construction between seg and fn configs
            got = other[field]
            if field == "states":
                for kg, (a, b) in enumerate(zip(expect, got)):
                    assert a == b, (
                        f"{base_name} vs {name}: state of key group {kg} differs:"
                        f"\n  {a!r}\n  {b!r}"
                    )
                continue
            assert got == expect, (
                f"{base_name} vs {name}: {field} differs:"
                f"\n  {str(expect)[:400]}\n  {str(got)[:400]}"
            )


# ---------------------------------------------------------------------------
# Job registry: the four real jobs plus the synthetic pipeline.
# ---------------------------------------------------------------------------

_KGS = 12  # small key-group counts keep the suite fast but multi-run


def _wiki_feeders():
    return {"wiki": wiki_edit_stream(StreamSpec(rate=90.0, seed=5))}


def _airline_feeders():
    return {"airline": airline_stream(StreamSpec(rate=90.0, seed=5))}


def _job4_feeders():
    return {
        "airline": airline_stream(StreamSpec(rate=90.0, seed=5)),
        "weather": weather_stream(StreamSpec(rate=40.0, seed=5)),
    }


def _int_batches(rate=120, key_space=10_000, seed=5):
    rng = np.random.default_rng(seed)
    tick = 0
    while True:
        n = int(rng.poisson(rate))
        keys = rng.integers(0, key_space, size=n).astype(np.int64)
        yield keys, rng.random(n), np.full(n, float(tick))
        tick += 1


def make_pipeline_topo(kgs: int = 16) -> Topology:
    """The synthetic source → re-key → recording-sink pipeline, with both
    operator protocols (shared with the migration property tests)."""

    def mid_fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys + 17, values, ts)

    def mid_seg(store, run_kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys + 17, values, ts), None

    def sink_fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys * 2, values, ts)

    def sink_seg(store, run_kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys * 2, values, ts), None

    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))
    t.add_operator(OperatorSpec("mid", mid_fn, num_keygroups=kgs, fn_seg=mid_seg))
    t.add_operator(
        OperatorSpec("sink", sink_fn, num_keygroups=kgs, is_sink=True, fn_seg=sink_seg)
    )
    t.connect("src", "mid")
    t.connect("mid", "sink")
    return t


def _pipeline_feeders():
    return {"src": _int_batches()}


JOBS = {
    "job1": (
        lambda: make_real_job_1(keygroups_per_op=_KGS, topk=3, window_ticks=4.0),
        _wiki_feeders,
    ),
    "job2": (lambda: real_job_2(keygroups_per_op=_KGS), _airline_feeders),
    "job3": (lambda: real_job_3(keygroups_per_op=_KGS), _airline_feeders),
    "job4": (lambda: real_job_4(keygroups_per_op=_KGS), _job4_feeders),
    "pipeline": (lambda: make_pipeline_topo(_KGS), _pipeline_feeders),
}

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus the engine wiring of the keygroup_partition histogram into SPL stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests skip cleanly without hypothesis; the rest still run
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _noop_decorator(*args, **kwargs):
        def wrap(fn):
            return fn

        return wrap

    given = settings = _noop_decorator

    class st:  # minimal strategy stand-ins so decorator args still evaluate
        @staticmethod
        def sampled_from(values):
            return None

        @staticmethod
        def integers(*args, **kwargs):
            return None


requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gemm.moe_gemm import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas

TOL = {
    jnp.float32: dict(atol=3e-5, rtol=3e-5),
    jnp.bfloat16: dict(atol=3e-2, rtol=3e-2),
}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,h,kv,hd,causal,window,dtype",
    [
        (1, 256, 4, 2, 64, True, None, jnp.float32),
        (2, 256, 4, 4, 32, True, None, jnp.float32),
        (1, 512, 8, 2, 64, True, 128, jnp.float32),
        (1, 256, 4, 1, 64, False, None, jnp.float32),
        (1, 256, 8, 8, 128, True, None, jnp.bfloat16),
        (2, 384, 6, 3, 64, True, None, jnp.float32),  # uneven block tail-free
    ],
)
def test_flash_attention_matches_ref(b, s, h, kv, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    bq = 128 if s % 128 == 0 else 64
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_kv=bq, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@requires_hypothesis
@settings(max_examples=6, deadline=None)
@given(
    s=st.sampled_from([128, 256]),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    seed=st.integers(0, 100),
)
def test_property_flash_attention(s, h, g, seed):
    kv = max(h // g, 1)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, s, kv, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, s, kv, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,kv,hd,t,dtype",
    [
        (3, 8, 2, 64, 512, jnp.float32),
        (1, 4, 4, 32, 256, jnp.float32),
        (2, 16, 2, 128, 512, jnp.bfloat16),
        (1, 2, 1, 64, 1024, jnp.float32),
    ],
)
def test_decode_attention_matches_ref(b, h, kv, hd, t, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, t, kv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, t, kv, hd), dtype)
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, t + 1, size=b), jnp.int32
    )
    out = decode_attention_pallas(q, kc, vc, kv_len, block_kv=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,w,bs,bw",
    [(2, 256, 256, 64, 128), (1, 128, 512, 128, 128), (3, 512, 128, 256, 128)],
)
def test_rglru_scan_matches_ref(b, s, w, bs, bw):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.random.uniform(ks[0], (b, s, w), jnp.float32, 0.2, 0.999)
    bb = jax.random.normal(ks[1], (b, s, w), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (b, w), jnp.float32)
    out = rglru_scan_pallas(a, bb, h0, block_seq=bs, block_width=bw, interpret=True)
    ref = rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@requires_hypothesis
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_rglru_scan_stability(seed):
    """With |a|<1 the recurrence must stay bounded (no blow-up)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(ks[0], (1, 128, 128), jnp.float32, 0.0, 0.99)
    b = jax.random.normal(ks[1], (1, 128, 128), jnp.float32)
    h0 = jnp.zeros((1, 128))
    out = rglru_scan_pallas(a, b, h0, block_seq=64, block_width=128, interpret=True)
    bound = float(jnp.abs(b).max()) / (1.0 - 0.99) + 1.0
    assert float(jnp.abs(out).max()) <= bound


# ---------------------------------------------------------------------------
# moe gemm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "e,c,d,f,dtype",
    [
        (4, 128, 256, 128, jnp.float32),
        (8, 64, 128, 256, jnp.float32),
        (2, 256, 512, 128, jnp.bfloat16),
    ],
)
def test_moe_gemm_matches_ref(e, c, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    w = (jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.05).astype(dtype)
    out = moe_gemm_pallas(x, w, block_c=64, block_d=128, block_f=64, interpret=True)
    ref = moe_gemm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


# ---------------------------------------------------------------------------
# keygroup_partition histogram wiring into SPL statistics
# ---------------------------------------------------------------------------


def _mk_pipeline(kgs=32):
    from repro.engine.topology import OperatorSpec, Topology

    def fwd(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys + 5, values, ts)

    def sink(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, []

    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))
    t.add_operator(OperatorSpec("mid", fwd, num_keygroups=kgs))
    t.add_operator(OperatorSpec("snk", sink, num_keygroups=kgs, is_sink=True))
    t.connect("src", "mid")
    t.connect("mid", "snk")
    return t


def test_kernel_histogram_wiring_matches_numpy_engine():
    """kernel_stats=True feeds the kernel's histogram into SPLWindow —
    routing, arrivals, and folded SPL statistics stay bit-identical to the
    numpy (np.bincount) engine."""
    from repro.engine import Engine, ExecutionConfig

    kern = Engine(_mk_pipeline(), 4, service_rate=1e9, seed=0,
                  config=ExecutionConfig(kernel_stats=True))
    ref = Engine(_mk_pipeline(), 4, service_rate=1e9, seed=0,
                 config=ExecutionConfig(kernel_stats=False))
    rng = np.random.default_rng(5)
    for t in range(4):
        keys = rng.integers(-(2**62), 2**62, size=257, dtype=np.int64)
        vals = rng.random(257)
        for eng in (kern, ref):
            eng.push_source("src", keys, vals, np.full(257, float(t)))
            eng.tick()
    for _ in range(3):
        kern.tick()
        ref.tick()
    assert np.array_equal(kern.window.kg_arrivals, ref.window.kg_arrivals)
    assert kern.window.kg_arrivals.sum() > 0
    assert kern.metrics.processed_tuples == ref.metrics.processed_tuples
    s1, s2 = kern.end_period(), ref.end_period()
    assert np.array_equal(s1.kg_load, s2.kg_load)
    assert np.array_equal(s1.kg_tuple_rate, s2.kg_tuple_rate)
    assert np.array_equal(s1.out_rates, s2.out_rates)


def test_kernel_histogram_wiring_nonint_keys_fall_back():
    """String keys can't ride the int-mix kernel: the engine silently uses
    the numpy path and the statistics remain correct."""
    from repro.engine import Engine, ExecutionConfig
    from repro.engine.topology import OperatorSpec, Topology

    def sink(state, keys, values, ts):
        return state, []

    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=8, is_source=True))
    t.add_operator(OperatorSpec("snk", sink, num_keygroups=8, is_sink=True))
    t.connect("src", "snk")
    eng = Engine(t, 2, service_rate=1e9, seed=0,
                 config=ExecutionConfig(kernel_stats=True))
    keys = np.array([f"user-{i % 13}" for i in range(99)])
    eng.push_source("src", keys, np.ones(99), np.zeros(99))
    eng.tick()
    eng.tick()
    assert eng.metrics.processed_tuples == 2 * 99
    assert eng.window.kg_arrivals.sum() == 2 * 99


def test_window_record_arrivals_accumulates_histogram():
    """SPLWindow.record_arrivals adds a kernel histogram at the op's base."""
    from repro.core.stats import SPLWindow

    w = SPLWindow(16)
    w.record_arrivals(4, np.array([1, 2, 3]))
    w.record_arrivals(4, np.array([1, 0, 1]))
    assert w.kg_arrivals[4:7].tolist() == [2.0, 2.0, 4.0]
    assert w.kg_arrivals.sum() == 8.0
    w.reset()
    assert w.kg_arrivals.sum() == 0.0

"""Balanced graph partitioning (METIS stand-in) quality and invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.solver.graphpart import (
    Graph,
    cut_weight,
    graph_from_dense,
    part_weights,
    partition_graph,
)


def ring_graph(n: int, w: float = 1.0) -> Graph:
    u = np.arange(n)
    return Graph(n, u, (u + 1) % n, np.full(n, w), np.ones(n))


def clustered_graph(clusters: int, size: int, seed: int = 0) -> Graph:
    """Dense intra-cluster edges, sparse inter-cluster — obvious best cut."""
    rng = np.random.default_rng(seed)
    n = clusters * size
    w = np.zeros((n, n))
    for c in range(clusters):
        lo = c * size
        blk = rng.uniform(5, 10, (size, size))
        w[lo : lo + size, lo : lo + size] = np.triu(blk, 1)
    # weak inter-cluster edges
    for c in range(clusters - 1):
        w[c * size, (c + 1) * size] = 0.01
    return graph_from_dense(w, np.ones(n))


def test_partition_covers_all_vertices():
    g = ring_graph(32)
    labels = partition_graph(g, 4)
    assert labels.shape == (32,)
    assert set(labels.tolist()) == {0, 1, 2, 3}


def test_balance_constraint():
    g = ring_graph(64)
    labels = partition_graph(g, 4, balance_tol=0.10)
    weights = part_weights(g, labels, 4)
    assert weights.max() <= (64 / 4) * 1.10 + 1e-9


def test_finds_natural_clusters():
    g = clustered_graph(4, 8)
    labels = partition_graph(g, 4)
    # Cut should avoid the heavy intra-cluster edges almost entirely.
    assert cut_weight(g, labels) < 0.1 * g.edge_w.sum()


def test_deterministic_given_seed():
    g = clustered_graph(3, 6, seed=1)
    a = partition_graph(g, 3, seed=42)
    b = partition_graph(g, 3, seed=42)
    np.testing.assert_array_equal(a, b)


def test_single_part():
    g = ring_graph(8)
    labels = partition_graph(g, 1)
    assert (labels == 0).all()


def test_parts_geq_vertices():
    g = ring_graph(4)
    labels = partition_graph(g, 8)
    assert labels.shape == (4,)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(6, 40),
    nparts=st.integers(2, 5),
    seed=st.integers(0, 999),
)
def test_property_partition_valid(n, nparts, seed):
    rng = np.random.default_rng(seed)
    w = np.triu(rng.uniform(0, 1, (n, n)) * (rng.random((n, n)) < 0.3), 1)
    g = graph_from_dense(w, rng.uniform(0.5, 2.0, n))
    labels = partition_graph(g, nparts, seed=seed)
    assert labels.shape == (n,)
    assert labels.min() >= 0 and labels.max() < nparts
    if nparts < n:
        weights = part_weights(g, labels, nparts)
        # Hard cap from _rebalance (tolerance + one heaviest vertex slack).
        cap = g.vertex_w.sum() / nparts * 1.10 + g.vertex_w.max()
        assert weights.max() <= cap + 1e-9

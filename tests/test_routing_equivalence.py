"""Vectorized routing must agree with per-tuple routing, bit for bit.

The batched `Topology.keygroups_of` (and the Pallas keygroup_partition kernel
in interpret mode) must produce exactly the key-group assignment of the
scalar `keygroup_of` across every key flavor the jobs use: int keys, string
keys, `key_fn` remapping, and `key_by_value` partitioning.
"""

import numpy as np
import pytest

from repro.engine.topology import OperatorSpec, Topology, hash_key, mix32, mix32_scalar


def _noop(state, keys, values, ts):
    return state, []


@pytest.fixture
def topo() -> Topology:
    t = Topology()
    t.add_operator(OperatorSpec("ints", None, num_keygroups=32, is_source=True))
    t.add_operator(OperatorSpec("strs", _noop, num_keygroups=8))
    t.add_operator(
        OperatorSpec("keyfn", _noop, num_keygroups=16, key_fn=lambda k: k % 7)
    )
    t.add_operator(
        OperatorSpec(
            "byval", _noop, num_keygroups=24, key_by_value=lambda v: v["part"]
        )
    )
    return t


def _scalar(t: Topology, op: int, keys, values) -> np.ndarray:
    return np.array(
        [t.keygroup_of(op, k, v) for k, v in zip(keys, values)], dtype=np.int64
    )


def test_int_keys_identical(topo):
    rng = np.random.default_rng(0)
    keys = rng.integers(-(2**62), 2**62, size=513, dtype=np.int64)
    keys[:3] = [0, -1, 2**62]  # edge keys
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(0, keys, values)
    assert np.array_equal(batched, _scalar(topo, 0, keys, values))
    lo, hi = topo.kg_base(0), topo.kg_base(0) + 32
    assert batched.min() >= lo and batched.max() < hi


def test_string_keys_identical(topo):
    keys = np.array([f"key-{i % 97}" for i in range(301)])
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(1, keys, values)
    assert np.array_equal(batched, _scalar(topo, 1, keys, values))


def test_key_fn_identical(topo):
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 10_000, size=257, dtype=np.int64)
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(2, keys, values)
    assert np.array_equal(batched, _scalar(topo, 2, keys, values))


@pytest.mark.parametrize("flavor", ["int", "str", "tuple"])
def test_key_by_value_identical(topo, flavor):
    rng = np.random.default_rng(2)
    n = 200
    if flavor == "int":
        parts = [int(x) for x in rng.integers(0, 500, size=n)]
    elif flavor == "str":
        parts = [f"route-{int(x)}" for x in rng.integers(0, 50, size=n)]
    else:
        parts = [(int(a), int(b)) for a, b in rng.integers(0, 30, size=(n, 2))]
    keys = np.arange(n, dtype=np.int64)
    values = np.empty(n, dtype=object)
    values[:] = [{"part": p} for p in parts]
    batched = topo.keygroups_of(3, keys, values)
    assert np.array_equal(batched, _scalar(topo, 3, keys, values))


def test_key_by_value_none_falls_back_to_key_fn(topo):
    """A None value routes via key_fn(key) in both the scalar and batched paths."""
    keys = np.arange(20, dtype=np.int64)
    values = np.empty(20, dtype=object)
    values[:10] = [{"part": int(i)} for i in range(10)]  # rest stay None
    batched = topo.keygroups_of(3, keys, values)
    assert np.array_equal(batched, _scalar(topo, 3, keys, values))


def test_empty_batch():
    from repro.kernels.keygroup_partition import keygroup_partition

    kg, hist = keygroup_partition(np.empty(0, dtype=np.int64), 8, force_pallas=True)
    assert len(kg) == 0 and hist.sum() == 0


def test_mix32_scalar_matches_vectorized():
    rng = np.random.default_rng(3)
    xs = rng.integers(-(2**62), 2**62, size=1000, dtype=np.int64)
    vec = mix32(xs)
    assert all(int(v) == mix32_scalar(int(x)) for x, v in zip(xs, vec))
    # hash_key for ints is the masked mix, not Python's hash.
    assert hash_key(12345) == mix32_scalar(12345) & 0x7FFFFFFF


def test_pallas_kernel_matches_engine(topo):
    """The TPU hash-partition kernel (interpret mode) == numpy group-by."""
    jax = pytest.importorskip("jax")
    del jax
    from repro.kernels.keygroup_partition import keygroup_partition

    rng = np.random.default_rng(4)
    keys = rng.integers(-(2**62), 2**62, size=1000, dtype=np.int64)
    values = np.empty(len(keys), dtype=object)
    expected = topo.keygroups_of(0, keys, values)
    base = topo.kg_base(0)
    for force_pallas in (False, True):  # jnp oracle and the Pallas kernel
        kg, hist = keygroup_partition(keys, 32, base=base, force_pallas=force_pallas)
        assert np.array_equal(kg, expected)
        assert np.array_equal(hist, np.bincount(expected - base, minlength=32))
        assert hist.sum() == len(keys)

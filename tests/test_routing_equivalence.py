"""Vectorized routing must agree with per-tuple routing, bit for bit.

The batched `Topology.keygroups_of` (and the Pallas keygroup_partition kernel
in interpret mode) must produce exactly the key-group assignment of the
scalar `keygroup_of` across every key flavor the jobs use: int keys, string
keys, `key_fn` remapping, and `key_by_value` partitioning.

The second half pins the structure-of-arrays work queue against the deque
oracle (`queue_impl="deque"`): identical tuple flow, identical SPL
statistics, and identical migration round-trips with in-flight queued
tuples.
"""

import numpy as np
import pytest

from repro.engine import Engine, ExecutionConfig
from repro.engine.topology import (
    OperatorSpec,
    Topology,
    hash_key,
    make_batch,
    mix32,
    mix32_scalar,
)
from repro.engine.workqueue import DequeWorkQueue, SoAWorkQueue


def _noop(state, keys, values, ts):
    return state, []


@pytest.fixture
def topo() -> Topology:
    t = Topology()
    t.add_operator(OperatorSpec("ints", None, num_keygroups=32, is_source=True))
    t.add_operator(OperatorSpec("strs", _noop, num_keygroups=8))
    t.add_operator(
        OperatorSpec("keyfn", _noop, num_keygroups=16, key_fn=lambda k: k % 7)
    )
    t.add_operator(
        OperatorSpec(
            "byval", _noop, num_keygroups=24, key_by_value=lambda v: v["part"]
        )
    )
    return t


def _scalar(t: Topology, op: int, keys, values) -> np.ndarray:
    return np.array(
        [t.keygroup_of(op, k, v) for k, v in zip(keys, values)], dtype=np.int64
    )


def test_int_keys_identical(topo):
    rng = np.random.default_rng(0)
    keys = rng.integers(-(2**62), 2**62, size=513, dtype=np.int64)
    keys[:3] = [0, -1, 2**62]  # edge keys
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(0, keys, values)
    assert np.array_equal(batched, _scalar(topo, 0, keys, values))
    lo, hi = topo.kg_base(0), topo.kg_base(0) + 32
    assert batched.min() >= lo and batched.max() < hi


def test_string_keys_identical(topo):
    keys = np.array([f"key-{i % 97}" for i in range(301)])
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(1, keys, values)
    assert np.array_equal(batched, _scalar(topo, 1, keys, values))


def test_key_fn_identical(topo):
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 10_000, size=257, dtype=np.int64)
    values = np.empty(len(keys), dtype=object)
    batched = topo.keygroups_of(2, keys, values)
    assert np.array_equal(batched, _scalar(topo, 2, keys, values))


@pytest.mark.parametrize("flavor", ["int", "str", "tuple"])
def test_key_by_value_identical(topo, flavor):
    rng = np.random.default_rng(2)
    n = 200
    if flavor == "int":
        parts = [int(x) for x in rng.integers(0, 500, size=n)]
    elif flavor == "str":
        parts = [f"route-{int(x)}" for x in rng.integers(0, 50, size=n)]
    else:
        parts = [(int(a), int(b)) for a, b in rng.integers(0, 30, size=(n, 2))]
    keys = np.arange(n, dtype=np.int64)
    values = np.empty(n, dtype=object)
    values[:] = [{"part": p} for p in parts]
    batched = topo.keygroups_of(3, keys, values)
    assert np.array_equal(batched, _scalar(topo, 3, keys, values))


def test_key_by_value_none_falls_back_to_key_fn(topo):
    """A None value routes via key_fn(key) in both the scalar and batched paths."""
    keys = np.arange(20, dtype=np.int64)
    values = np.empty(20, dtype=object)
    values[:10] = [{"part": int(i)} for i in range(10)]  # rest stay None
    batched = topo.keygroups_of(3, keys, values)
    assert np.array_equal(batched, _scalar(topo, 3, keys, values))


def test_empty_batch():
    from repro.kernels.keygroup_partition import keygroup_partition

    kg, hist = keygroup_partition(np.empty(0, dtype=np.int64), 8, force_pallas=True)
    assert len(kg) == 0 and hist.sum() == 0


def test_mix32_scalar_matches_vectorized():
    rng = np.random.default_rng(3)
    xs = rng.integers(-(2**62), 2**62, size=1000, dtype=np.int64)
    vec = mix32(xs)
    assert all(int(v) == mix32_scalar(int(x)) for x, v in zip(xs, vec))
    # hash_key for ints is the masked mix, not Python's hash.
    assert hash_key(12345) == mix32_scalar(12345) & 0x7FFFFFFF


def test_pallas_kernel_matches_engine(topo):
    """The TPU hash-partition kernel (interpret mode) == numpy group-by."""
    jax = pytest.importorskip("jax")
    del jax
    from repro.kernels.keygroup_partition import keygroup_partition

    rng = np.random.default_rng(4)
    keys = rng.integers(-(2**62), 2**62, size=1000, dtype=np.int64)
    values = np.empty(len(keys), dtype=object)
    expected = topo.keygroups_of(0, keys, values)
    base = topo.kg_base(0)
    for force_pallas in (False, True):  # jnp oracle and the Pallas kernel
        kg, hist = keygroup_partition(keys, 32, base=base, force_pallas=force_pallas)
        assert np.array_equal(kg, expected)
        assert np.array_equal(hist, np.bincount(expected - base, minlength=32))
        assert hist.sum() == len(keys)


# ---------------------------------------------------------------------------
# SoA work queue vs the deque oracle
# ---------------------------------------------------------------------------


def _sum_op(shift):
    def fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys + shift, values, ts)

    return fn


def _recording_sink(state, keys, values, ts):
    state.setdefault("seen", []).extend(keys.tolist())
    return state, list(zip((keys * 2).tolist(), values.tolist(), ts.tolist()))


def _pipeline_topo(kgs=16):
    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))
    t.add_operator(OperatorSpec("mid", _sum_op(17), num_keygroups=kgs))
    t.add_operator(
        OperatorSpec("sink", _recording_sink, num_keygroups=kgs, is_sink=True)
    )
    t.connect("src", "mid")
    t.connect("mid", "sink")
    return t


def _make_engines(service_rate=1e9, num_nodes=4, seed=0, kgs=16):
    """One SoA engine and one deque engine, identically configured."""
    return tuple(
        Engine(
            _pipeline_topo(kgs),
            num_nodes,
            service_rate=service_rate,
            seed=seed,
            config=ExecutionConfig(queue_impl=impl),
        )
        for impl in ("soa", "deque")
    )


def _drive(eng, ticks=12, batch=300, seed=3):
    rng = np.random.default_rng(seed)
    pushed = 0
    for t in range(ticks):
        keys = rng.integers(0, 10_000, size=batch).astype(np.int64)
        pushed += eng.push_source(
            "src",
            keys,
            rng.random(batch),
            np.full(batch, float(t)),
        )
        eng.tick()
    for _ in range(4):  # drain stragglers
        eng.tick()
    return pushed


def test_soa_matches_deque_tuple_flow():
    """Identical inputs → bit-identical tuple flow through both queues."""
    soa, dq = _make_engines()
    assert _drive(soa) == _drive(dq)
    for m in ("processed_tuples", "emitted_tuples", "cross_node_tuples",
              "intra_node_tuples", "sink_tuples", "dropped_credits"):
        assert getattr(soa.metrics, m) == getattr(dq.metrics, m), m
    # Sink outputs: exactly the same tuples in exactly the same order.
    assert soa.metrics.sink_outputs == dq.metrics.sink_outputs
    assert len(soa.metrics.sink_outputs) > 0


def test_soa_matches_deque_spl_statistics():
    """Folded SPL statistics are bit-identical across queue implementations."""
    soa, dq = _make_engines()
    _drive(soa)
    _drive(dq)
    s1, s2 = soa.end_period(), dq.end_period()
    assert np.array_equal(s1.kg_load, s2.kg_load)
    assert np.array_equal(s1.kg_tuple_rate, s2.kg_tuple_rate)
    assert np.array_equal(s1.out_pairs.src, s2.out_pairs.src)
    assert np.array_equal(s1.out_pairs.dst, s2.out_pairs.dst)
    assert np.array_equal(s1.out_pairs.rate, s2.out_pairs.rate)
    assert np.array_equal(s1.out_rates, s2.out_rates)  # dense property view
    assert s1.out_rates.sum() > 0


def test_soa_matches_deque_under_backpressure():
    """Tight service budgets exercise partial drains / cursor resumption."""
    soa, dq = _make_engines(service_rate=60.0)
    assert _drive(soa, ticks=30) == _drive(dq, ticks=30)
    assert soa.metrics.processed_tuples == dq.metrics.processed_tuples
    assert soa.metrics.sink_outputs == dq.metrics.sink_outputs
    # The budget was actually binding: a backlog survived the run, and the
    # credit controller throttled the sources identically on both engines.
    assert soa.metrics.dropped_credits == dq.metrics.dropped_credits
    assert soa.metrics.dropped_credits > 0
    assert [q.cost for q in soa._queues] == [q.cost for q in dq._queues]


def test_migration_roundtrip_preserves_inflight_tuples():
    """redirect → serialize → install with queued tuples, both queue impls.

    Tuples queued for the migrating key group at redirect time must follow
    σ_k to the destination and replay there, preserving exactly the tuples
    and ordering the deque implementation delivers.
    """
    results = []
    for impl in ("soa", "deque"):
        eng = Engine(_pipeline_topo(), 4, service_rate=1e9, seed=0,
                     config=ExecutionConfig(queue_impl=impl))
        rng = np.random.default_rng(7)
        keys = rng.integers(0, 10_000, size=400).astype(np.int64)
        vals = rng.random(400)
        # Push twice without ticking: work is queued at the current owners.
        eng.push_source("src", keys, vals, np.zeros(400))
        eng.tick()  # src → mid queued
        # mid's key groups now hold queued state; migrate one mid-flight.
        kg = int(eng.topology.kg_base(1)) + 3
        src_node = eng.router.node_of(kg)
        dst = (src_node + 1) % eng.num_nodes
        eng.redirect(kg, dst)
        # More traffic while in flight buffers behind the migration.
        eng.push_source("src", keys + 1, vals, np.ones(400))
        eng.tick()
        blob = eng.serialize(kg)
        eng.install(kg, dst, blob)
        for _ in range(5):
            eng.tick()
        assert not eng.router.in_flight
        assert eng.router.node_of(kg) == dst
        mid_states = [s.get("n", 0) for _, s in eng.store.items()]
        results.append(
            (
                eng.metrics.processed_tuples,
                eng.metrics.emitted_tuples,
                eng.metrics.sink_outputs,
                mid_states,
            )
        )
    assert results[0][0] == results[1][0]
    assert results[0][1] == results[1][1]
    assert results[0][2] == results[1][2]  # same tuples, same order
    assert results[0][3] == results[1][3]  # per-kg state counts identical
    assert len(results[0][2]) > 0


@pytest.mark.parametrize("queue_cls", [SoAWorkQueue, DequeWorkQueue])
def test_extract_keygroup_masks_out_queued_runs(queue_cls):
    """extract_keygroup removes exactly one key group's batches, in order."""
    q = queue_cls()
    k1 = make_batch([1, 2, 3], [0.1, 0.2, 0.3], [0.0, 0.0, 0.0])
    keys = np.array([10, 10, 20, 20, 30])
    vals = np.empty(5, dtype=object)
    vals[:] = list(range(5))
    ts = np.zeros(5)
    q.push_runs(1, keys, vals, ts, [5, 6, 7], [0, 2, 4], [2, 4, 5], [2.0, 2.0, 1.0])
    q.push_batch(1, 6, k1, 3.0)
    assert q.cost == 8.0
    batches, removed = q.extract_keygroup(6)
    assert removed == 5.0
    assert q.cost == 3.0
    # FIFO: first the queued run (keys 20,20), then the later batch (1,2,3).
    assert [b[0].tolist() for b in batches] == [[20, 20], [1, 2, 3]]
    # Remaining runs are untouched and drain normally.
    drained = []
    q.drain(
        1e9,
        lambda node,
        op,
        kg,
        k,
        v,
        t: drained.append((kg, k.tolist())),
        0,
        [],
        [],
    )
    assert drained == [(5, [10, 10]), (7, [30])]
    assert q.cost == 0.0


def test_engine_arrival_histograms_match_scalar_routing():
    """window.kg_arrivals == per-kg tuple counts of the scalar assignment."""
    eng = Engine(_pipeline_topo(), 3, service_rate=1e9, seed=1)
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 5_000, size=500).astype(np.int64)
    eng.push_source("src", keys, rng.random(500), np.zeros(500))
    eng.tick()
    expected = np.zeros(eng.topology.num_keygroups)
    values = np.empty(len(keys), dtype=object)
    src_kgs = eng.topology.keygroups_of(0, keys, values)
    np.add.at(expected, src_kgs, 1.0)
    mid_kgs = eng.topology.keygroups_of(1, keys, values)  # pass-through keys
    np.add.at(expected, mid_kgs, 1.0)
    assert np.array_equal(eng.window.kg_arrivals, expected)


# ---------------------------------------------------------------------------
# segment-vectorized operator protocol (fn_seg) vs the per-run fn
# ---------------------------------------------------------------------------


def _pipeline_topo_seg(kgs=16):
    """Same pipeline as _pipeline_topo but with fn_seg implementations."""
    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))

    def mid_seg(store, run_kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys + 17, values, ts), None

    t.add_operator(
        OperatorSpec("mid", _sum_op(17), num_keygroups=kgs, fn_seg=mid_seg)
    )

    def sink_seg(store, run_kgs, starts, ends, keys, values, ts):
        ok, ov, ot = [], [], []
        for kg, a, z in zip(run_kgs, starts, ends):
            st = store[kg]
            st.setdefault("seen", []).extend(keys[a:z].tolist())
            ok.append(keys[a:z] * 2)
            ov.append(values[a:z])
            ot.append(ts[a:z])
        out = (np.concatenate(ok), np.concatenate(ov), np.concatenate(ot))
        return out, None

    t.add_operator(
        OperatorSpec(
            "sink", _recording_sink, num_keygroups=kgs, is_sink=True, fn_seg=sink_seg
        )
    )
    t.connect("src", "mid")
    t.connect("mid", "sink")
    return t


def test_fn_seg_matches_per_run_fn():
    """The segment-vectorized protocol delivers bit-identical tuple flow,
    state, and SPL statistics to the per-run fn (which the deque oracle
    always uses) — the contract the throughput benchmark relies on."""
    seg_eng = Engine(_pipeline_topo_seg(), 4, service_rate=1e9, seed=0)
    run_eng = Engine(_pipeline_topo(), 4, service_rate=1e9, seed=0)
    oracle = Engine(
        _pipeline_topo_seg(),
        4,
        service_rate=1e9,
        seed=0,
        config=ExecutionConfig(queue_impl="deque"),
    )
    for eng in (seg_eng, run_eng, oracle):
        _drive(eng)
    assert seg_eng.metrics.processed_tuples == run_eng.metrics.processed_tuples
    assert seg_eng.metrics.emitted_tuples == run_eng.metrics.emitted_tuples
    assert seg_eng.metrics.sink_outputs == run_eng.metrics.sink_outputs
    assert seg_eng.metrics.sink_outputs == oracle.metrics.sink_outputs
    # Per-key-group operator state is identical under both protocols.
    for kg in range(seg_eng.topology.num_keygroups):
        assert seg_eng.store.get(kg).get("n") == run_eng.store.get(kg).get("n")
        assert seg_eng.store.get(kg).get("seen") == run_eng.store.get(kg).get("seen")
    s1, s2 = seg_eng.end_period(), run_eng.end_period()
    assert np.array_equal(s1.kg_load, s2.kg_load)
    assert np.array_equal(s1.out_rates, s2.out_rates)
    assert np.array_equal(s1.kg_tuple_rate, s2.kg_tuple_rate)


def test_fn_seg_falls_back_to_fn_after_migration():
    """Non-contiguous segments (in-flight migrations, extraction rebuilds)
    take the per-run fn path — results stay identical to the fn-only job."""
    engines = []
    for topo_fn in (_pipeline_topo_seg, _pipeline_topo):
        eng = Engine(topo_fn(), 4, service_rate=1e9, seed=0)
        rng = np.random.default_rng(9)
        keys = rng.integers(0, 10_000, size=350).astype(np.int64)
        eng.push_source("src", keys, rng.random(350), np.zeros(350))
        eng.tick()
        kg = int(eng.topology.kg_base(1)) + 5
        dst = (eng.router.node_of(kg) + 1) % eng.num_nodes
        eng.redirect(kg, dst)
        eng.push_source("src", keys + 3, rng.random(350), np.ones(350))
        eng.tick()
        eng.install(kg, dst, eng.serialize(kg))
        for _ in range(5):
            eng.tick()
        engines.append(eng)
    a, b = engines
    assert a.metrics.processed_tuples == b.metrics.processed_tuples
    assert a.metrics.sink_outputs == b.metrics.sink_outputs


def test_soa_matches_deque_multiple_pushes_per_tick():
    """Several pushes to the same op between ticks, under a binding budget —
    both queues must drain the identical run sequence (regression: the old
    deque oracle coalesced same-tick (op, kg) entries and diverged here)."""
    soa, dq = _make_engines(service_rate=50.0, num_nodes=1)
    rng1, rng2 = np.random.default_rng(13), np.random.default_rng(13)
    per_tick = ([], [])
    for t in range(15):
        for _ in range(3):  # multiple same-op pushes within one tick gap
            k1 = rng1.integers(0, 1000, size=40).astype(np.int64)
            k2 = rng2.integers(0, 1000, size=40).astype(np.int64)
            soa.push_source("src", k1, rng1.random(40), np.full(40, float(t)))
            dq.push_source("src", k2, rng2.random(40), np.full(40, float(t)))
        soa.tick()
        dq.tick()
        per_tick[0].append(soa.metrics.processed_tuples)
        per_tick[1].append(dq.metrics.processed_tuples)
    assert per_tick[0] == per_tick[1]
    assert soa.metrics.sink_outputs == dq.metrics.sink_outputs
    assert [q.cost for q in soa._queues] == [q.cost for q in dq._queues]


def test_mix32_rejects_bit_reinterpretation():
    """The uint32-lane fast path only fires for native 64-bit ints — other
    dtypes take the value-converting path and match the scalar mix."""
    vals = [1, 2, 2**40, -7]
    for arr in (
        np.array(vals, dtype=np.float64),          # 8-byte but not integer
        np.array(vals, dtype=np.int64)[::2],       # non-contiguous view
        np.array([1, 2, 7, -7], dtype=np.int32),   # narrow lanes
        np.array(vals, dtype=np.int64).astype(">i8"),  # non-native order
    ):
        expected = [mix32_scalar(int(v)) for v in arr.tolist()]
        assert [int(h) for h in mix32(arr)] == expected, arr.dtype


def test_soa_matches_deque_nondyadic_costs():
    """Non-power-of-two operator costs under a binding budget: float
    accounting must follow the identical trajectory on both queues
    (regression: bulk budget subtraction used a different summation order)."""
    def topo_nd(kgs=16):
        t = Topology()
        t.add_operator(OperatorSpec("src", None, num_keygroups=kgs, is_source=True))
        t.add_operator(
            OperatorSpec("mid", _sum_op(17), num_keygroups=kgs, cost_per_tuple=1.2)
        )
        t.add_operator(
            OperatorSpec(
                "sink",
                _recording_sink,
                num_keygroups=kgs,
                is_sink=True,
                cost_per_tuple=0.3,
            )
        )
        t.connect("src", "mid")
        t.connect("mid", "sink")
        return t

    for seed in (0, 1, 2):
        soa = Engine(topo_nd(), 3, service_rate=70.0, seed=seed,
                     config=ExecutionConfig(queue_impl="soa"))
        dq = Engine(topo_nd(), 3, service_rate=70.0, seed=seed,
                    config=ExecutionConfig(queue_impl="deque"))
        assert _drive(soa, ticks=25, seed=seed) == _drive(dq, ticks=25, seed=seed)
        assert soa.metrics.processed_tuples == dq.metrics.processed_tuples, seed
        assert soa.metrics.sink_outputs == dq.metrics.sink_outputs
        assert [q.cost for q in soa._queues] == [q.cost for q in dq._queues]


def test_fn_seg_filter_with_out_counts():
    """A filtering fn_seg returns out_counts; attribution must line up with
    the per-run fn oracle, and inconsistent counts raise immediately."""
    def topo_filter(fn_seg_impl):
        def fn(state, keys, values, ts):
            keep = keys % 2 == 0
            return state, (keys[keep], values[keep], ts[keep])

        t = Topology()
        t.add_operator(OperatorSpec("src", None, num_keygroups=8, is_source=True))
        t.add_operator(
            OperatorSpec("mid", fn, num_keygroups=8, fn_seg=fn_seg_impl)
        )
        t.add_operator(
            OperatorSpec("sink", _recording_sink, num_keygroups=8, is_sink=True)
        )
        t.connect("src", "mid")
        t.connect("mid", "sink")
        return t

    def good_seg(store, kgs, starts, ends, keys, values, ts):
        keep = keys % 2 == 0
        lens = [int(keep[a:z].sum()) for a, z in zip(starts, ends)]
        return (keys[keep], values[keep], ts[keep]), lens

    seg_eng = Engine(topo_filter(good_seg), 2, service_rate=1e9, seed=0)
    ref_eng = Engine(topo_filter(None), 2, service_rate=1e9, seed=0)
    for eng in (seg_eng, ref_eng):
        _drive(eng, ticks=6)
    assert seg_eng.metrics.sink_outputs == ref_eng.metrics.sink_outputs
    assert len(seg_eng.metrics.sink_outputs) > 0
    s1, s2 = seg_eng.end_period(), ref_eng.end_period()
    assert np.array_equal(s1.out_rates, s2.out_rates)

    def bad_seg(store, kgs, starts, ends, keys, values, ts):
        keep = keys % 2 == 0
        return (keys[keep], values[keep], ts[keep]), [0] * len(kgs)  # wrong sums

    bad_eng = Engine(topo_filter(bad_seg), 2, service_rate=1e9, seed=0)
    bad_eng.push_source("src", np.arange(64), np.ones(64), np.zeros(64))
    bad_eng.tick()
    with pytest.raises(ValueError, match="out_counts"):
        bad_eng.tick()  # mid drains on the second tick

"""kg_tuple_rate as a leading-load signal in ALBIC's node scoring.

Mirror of the scaler-side rate projection (tests/test_scaling_rate_signal.py):
step 3 of Algorithm 2 pins a new collocation pair to the less-loaded of the
two candidate nodes.  With the rate signal, "less loaded" means less loaded
*one period ahead* — a node that is merely currently-balanced but hosts a
surging key group scores as loaded, and the migration targets the other node.
"""

import numpy as np

from repro.core.albic import AlbicParams, albic
from repro.core.framework import AdaptationFramework
from repro.core.stats import ClusterState

# Two operators × two key groups: kg 0/1 belong to op 0, kg 2/3 to op 1.
# The only hot pair is 0 → 2 (kg 0 on node 0, kg 2 on node 1), so step 3
# case 1 fires: both ends pinned to whichever node scores less loaded.
_KG_OP = [0, 0, 1, 1]
_ALLOC = [0, 0, 1, 1]
_DOWNSTREAM = {0: [1], 1: []}


def _state(rate):
    out = np.zeros((4, 4))
    out[0, 2] = 50.0
    return ClusterState.create(
        2,
        np.asarray(_KG_OP),
        np.full(4, 10.0),  # node loads [20, 20]: currently balanced
        np.asarray(_ALLOC),
        out_rates=out,
        downstream=_DOWNSTREAM,
        kg_tuple_rate=np.asarray(rate, dtype=np.float64),
    )


_FLAT_PREV = np.full(4, 10.0)
# kg 1 (node 0) arrivals are surging 4×: node 0 projects to 10 + 40 = 50
# load points versus node 1's 20 — node 0 is about to overload.
_SURGE_NOW = [10.0, 40.0, 10.0, 10.0]


def test_surging_node_is_steered_away_from():
    st = _state(_SURGE_NOW)
    res = albic(st, params=AlbicParams(seed=0), prev_rate=_FLAT_PREV)
    assert res.pinned_pair == (0, 2)
    # Both ends of the pinned pair land on node 1 — away from the node the
    # surge is about to overload, even though measured loads tie at 20/20.
    assert res.plan.alloc[0] == res.plan.alloc[2] == 1


def test_without_rate_signal_ties_break_to_first_node():
    st = _state(_SURGE_NOW)
    # No history → projection unavailable → measured loads tie → n1 (node 0).
    res = albic(st, params=AlbicParams(seed=0))
    assert res.pinned_pair == (0, 2)
    assert res.plan.alloc[0] == res.plan.alloc[2] == 0
    # Same with the signal explicitly disabled despite available history.
    res = albic(
        st,
        params=AlbicParams(seed=0, use_rate_signal=False),
        prev_rate=_FLAT_PREV,
    )
    assert res.plan.alloc[0] == res.plan.alloc[2] == 0


def test_flat_rates_match_measured_scoring():
    st = _state([10.0, 10.0, 10.0, 10.0])
    with_signal = albic(st, params=AlbicParams(seed=0), prev_rate=_FLAT_PREV)
    without = albic(st, params=AlbicParams(seed=0))
    assert np.array_equal(with_signal.plan.alloc, without.plan.alloc)


def test_framework_threads_prev_rate_between_periods():
    fw = AdaptationFramework(mode="albic", albic_params=AlbicParams(seed=0))
    assert fw._prev_rate is None
    fw.adapt(_state(_SURGE_NOW))
    assert fw._prev_rate is not None
    assert fw._prev_rate.tolist() == _SURGE_NOW

"""Deterministic pins for the stateful fuzz operators (window / accum).

The hypothesis-driven fuzz suite draws these kinds too, but it skips when
hypothesis is unavailable — these fixed specs keep the windowed and keyed
accumulator operators exercised on every run, across both value families,
schema mixes and migrations.
"""

import pytest

from conformance import (
    Scenario,
    assert_equivalent,
    fuzz_feeders,
    make_fuzz_topology,
    run_configs,
)

SPECS = {
    "scalar-window-accum": {
        "family": "scalar",
        "key_dtype": "i8",
        "source_schema": True,
        "ops": [
            {
                "kind": "window",
                "kgs": 8,
                "schema": True,
                "out_schema": True,
                "key": "id",
            },
            {
                "kind": "accum",
                "kgs": 12,
                "schema": False,
                "out_schema": False,
                "key": "mod",
            },
        ],
        "edges": [[-1], [0]],
    },
    "record-window-accum": {
        "family": "record",
        "key_dtype": "i4",
        "source_schema": True,
        "ops": [
            {
                "kind": "accum",
                "kgs": 8,
                "schema": True,
                "out_schema": False,
                "key": "byval",
            },
            {
                "kind": "window",
                "kgs": 8,
                "schema": False,
                "out_schema": True,
                "key": "id",
            },
        ],
        "edges": [[-1], [0]],
    },
}


@pytest.mark.parametrize("name", list(SPECS), ids=str)
@pytest.mark.parametrize("migrate", [(), (3, 6)], ids=["steady", "migrate"])
def test_stateful_fuzz_ops_conform(name, migrate):
    spec = SPECS[name]
    scenario = Scenario("stateful", ticks=10, drain_ticks=6, migrate_at=migrate)
    results = run_configs(
        lambda: make_fuzz_topology(spec), fuzz_feeders(spec), scenario
    )
    assert_equivalent(results)
    seg = results["soa+seg+schema"]
    assert seg["metrics"]["processed_tuples"] > 0
    assert seg["seg_calls"] > 0
    # The stateful bodies really accreted state (window buffers / keyed
    # sums live in σ_k, so migrations moved them too).
    assert any(s != ("dict", []) for s in seg["states"])

"""ALBIC (§4.3.2, Algorithm 2) behaviour."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import AlbicParams, albic, solve_allocation
from repro.core.albic import _score_pairs, _split_set, _union_sets

from conftest import make_cluster


def test_albic_respects_max_ld():
    state = make_cluster(seed=1)
    res = albic(
        state,
        max_migr_cost=200.0,
        params=AlbicParams(max_ld=10.0, time_limit=3.0),
    )
    assert res.plan.status != "infeasible"
    assert res.plan.load_distance <= 10.0 + 1e-6 or res.retries > 0


def test_albic_increases_collocation_over_rounds():
    state = make_cluster(seed=2, one_to_one_frac=0.8)
    start = state.collocation_factor()
    for i in range(8):
        res = albic(
            state,
            max_migrations=10,
            params=AlbicParams(max_ld=15.0, time_limit=2.0, seed=i),
        )
        state = state.copy()
        state.alloc = res.plan.alloc
    assert state.collocation_factor() > start + 5.0


def test_albic_degenerates_to_milp_at_zero_pl():
    state = make_cluster(seed=3)
    res = albic(
        state,
        max_migr_cost=100.0,
        params=AlbicParams(max_pl=0.0, time_limit=3.0),
    )
    pure = solve_allocation(state, max_migr_cost=100.0, time_limit=3.0)
    assert res.units == [] and res.pinned_pair is None
    assert abs(res.plan.load_distance - pure.load_distance) < 2.0


def test_score_pairs_selects_heavy_edges():
    state = make_cluster(seed=4, one_to_one_frac=0.5)
    col, tobe = _score_pairs(state, score_factor=1.5)
    pairs = col + [(a, b) for a, b, _ in tobe]
    assert pairs, "no candidate pairs found"
    # Every selected pair must exceed the sF·avg threshold by construction.
    for gi, gj in pairs:
        downs = state.downstream[int(state.kg_operator[gi])]
        down_kgs = np.concatenate([np.where(state.kg_operator == d)[0] for d in downs])
        avg = state.out_rates[gi, down_kgs].sum() / len(down_kgs)
        assert state.out_rates[gi, gj] > 1.5 * avg


def test_union_sets_merges_transitively():
    sets = _union_sets([(1, 2), (2, 3), (7, 8), (9, 7)])
    as_sets = {frozenset(s) for s in sets}
    assert frozenset({1, 2, 3}) in as_sets
    assert frozenset({7, 8, 9}) in as_sets


def test_split_set_respects_constraints():
    state = make_cluster(seed=5)
    members = list(range(12))
    rng = np.random.default_rng(0)
    parts = _split_set(
        state, members, max_migr_cost=25.0, max_pl=3.0, alpha=1.0, rng=rng
    )
    covered = sorted(g for p in parts for g in p)
    assert covered == members
    for p in parts:
        if len(p) > 1:
            assert state.kg_load[p].sum() <= 3.0 + max(state.kg_load[p])  # split sanity
            assert state.migration_costs()[p].sum() <= 25.0 + max(
                state.migration_costs()[p]
            )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), sf=st.floats(1.0, 3.0))
def test_property_albic_valid_allocation(seed, sf):
    state = make_cluster(num_nodes=4, kgs_per_op=8, num_ops=3, seed=seed)
    res = albic(
        state,
        max_migrations=8,
        params=AlbicParams(score_factor=sf, time_limit=2.0, seed=seed),
    )
    assert ((res.plan.alloc >= 0) & (res.plan.alloc < 4)).all()
    assert res.plan.num_migrations <= 8

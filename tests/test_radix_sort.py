"""Radix-sort kernel suite: the Pallas bucketed counting argsort must be
bit-identical (not allclose — identical permutations) to numpy's stable
radix argsort, the CPU data plane's routing sort, in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _noop_decorator(*args, **kwargs):
        def wrap(fn):
            return fn

        return wrap

    given = settings = _noop_decorator

    class st:
        @staticmethod
        def integers(*args, **kwargs):
            return None

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)

from repro.kernels.radix_sort import bucket_argsort, bucket_argsort_jax
from repro.kernels.radix_sort.radix_sort import bucket_argsort_pallas
from repro.kernels.radix_sort.ref import bucket_argsort_ref


@pytest.mark.parametrize(
    "n,nb,block",
    [
        (0, 4, 512),       # empty
        (1, 1, 512),       # single element, single bucket
        (7, 3, 4),         # multiple partial blocks
        (512, 16, 512),    # exactly one block
        (513, 16, 512),    # one-past-block tail
        (1024, 2, 128),    # heavy duplicate pressure across blocks
        (2000, 257, 512),  # bucket count not a power of two
    ],
)
def test_pallas_matches_numpy_stable_argsort(n, nb, block):
    rng = np.random.default_rng(n * 31 + nb)
    codes = rng.integers(0, nb, size=n).astype(np.int32)
    ref = bucket_argsort_ref(codes)
    if n == 0:
        assert bucket_argsort(codes, nb).size == 0
        return
    out = bucket_argsort_pallas(
        jnp.asarray(codes), num_buckets=nb, block=block, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_all_equal_codes_keep_input_order():
    codes = np.zeros(300, dtype=np.int32)
    out = bucket_argsort_pallas(
        jnp.asarray(codes), num_buckets=1, block=64, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.arange(300))


def test_host_dispatch_cpu_uses_numpy_handoff():
    """On CPU the host wrapper is numpy's radix argsort verbatim."""
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 40, size=777)
    np.testing.assert_array_equal(
        bucket_argsort(codes, 40), np.argsort(codes, kind="stable")
    )


def test_host_dispatch_force_pallas_interpret():
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 12, size=333)
    np.testing.assert_array_equal(
        bucket_argsort(codes, 12, force_pallas=True),
        np.argsort(codes, kind="stable"),
    )


def test_traceable_entry_matches_numpy():
    """bucket_argsort_jax (the fused superstep's in-jit routing sort) must
    produce the identical stable permutation on every backend."""
    rng = np.random.default_rng(2)
    codes = rng.integers(0, 64, size=1500).astype(np.int64)
    out = bucket_argsort_jax(jnp.asarray(codes), 64)
    np.testing.assert_array_equal(
        np.asarray(out), np.argsort(codes, kind="stable")
    )


@requires_hypothesis
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 1500),
    nb=st.integers(1, 300),
    block=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
def test_property_bit_identical_permutation(n, nb, block, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, nb, size=n).astype(np.int32)
    out = bucket_argsort_pallas(
        jnp.asarray(codes),
        num_buckets=nb,
        block=2**block,
        interpret=True,
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.argsort(codes, kind="stable")
    )

"""The §5.1 synthetic cluster generator: the ±varies node-load spread."""

import numpy as np
import pytest

from benchmarks.common import synthetic_cluster


@pytest.mark.parametrize("varies", [10.0, 20.0])
def test_synthetic_cluster_spread(varies):
    """20% of nodes sit ±varies/2 percent off the pack, the rest tight."""
    state = synthetic_cluster(20, 400, 10, varies=varies, seed=3)
    loads = state.node_loads()
    med = float(np.median(loads))
    half = varies / 2.0 / 100.0
    # The adjusted nodes bracket the distribution at ±varies/2 of the median
    # (key-group-level ±5% jitter averages out over 20 key groups per node).
    assert abs(loads.max() / med - (1.0 + half)) < 0.03
    assert abs(loads.min() / med - (1.0 - half)) < 0.03
    # Exactly ~60% mean utilization as specified in §5.1.
    assert abs(med - 60.0) / 60.0 < 0.05


def test_synthetic_cluster_shapes():
    state = synthetic_cluster(8, 160, 4, one_to_one_pct=50.0, seed=0)
    assert state.num_nodes == 8
    assert state.num_keygroups == 160
    assert state.out_rates.shape == (160, 160)
    # Even allocation round-robins key groups over nodes.
    assert np.bincount(state.alloc, minlength=8).std() == 0

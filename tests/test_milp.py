"""MILP (§4.3.1) invariants: constraints, budgets, Lemmas 1–2, extensions."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import solve_allocation
from repro.core.scaling import ScalingDecision, apply_scaling

from conftest import make_cluster


def test_assignment_complete(cluster):
    plan = solve_allocation(cluster, max_migr_cost=50.0, time_limit=5.0)
    assert plan.status in ("optimal", "time_limit")
    assert plan.alloc.shape == (cluster.num_keygroups,)
    assert ((plan.alloc >= 0) & (plan.alloc < cluster.num_nodes)).all()


def test_migration_cost_budget(cluster):
    budget = 30.0
    plan = solve_allocation(cluster, max_migr_cost=budget, time_limit=5.0)
    assert plan.migration_cost <= budget + 1e-6


def test_migration_count_budget(cluster):
    plan = solve_allocation(cluster, max_migrations=5, time_limit=5.0)
    assert plan.num_migrations <= 5


def test_improves_load_distance(cluster):
    before = cluster.load_distance()
    plan = solve_allocation(cluster, max_migr_cost=100.0, time_limit=5.0)
    assert plan.load_distance <= before + 1e-9


def test_unrestricted_beats_restricted(cluster):
    tight = solve_allocation(cluster, max_migrations=3, time_limit=5.0)
    free = solve_allocation(cluster, time_limit=5.0)
    assert free.load_distance <= tight.load_distance + 1e-6


def test_zero_budget_is_identity(cluster):
    plan = solve_allocation(cluster, max_migr_cost=0.0, time_limit=5.0)
    assert plan.num_migrations == 0
    np.testing.assert_array_equal(plan.alloc, cluster.alloc)


def test_pins_respected(cluster):
    # Pin key groups 0 and 1 (as singleton units) to node 3.
    plan = solve_allocation(
        cluster,
        max_migr_cost=1e9,
        units=[[0], [1]],
        pins={0: 3, 1: 3},
        time_limit=5.0,
    )
    assert plan.alloc[0] == 3 and plan.alloc[1] == 3


def test_units_move_together(cluster):
    unit = [0, 5, 9]
    plan = solve_allocation(cluster, max_migr_cost=1e9, units=[unit], time_limit=5.0)
    assert len({int(plan.alloc[k]) for k in unit}) == 1


def test_lemma1_no_migration_into_b(cluster):
    """Lemma 1: no key group migrates from A to B (marked-for-removal)."""
    state = cluster.copy()
    state.kill[1] = True
    plan = solve_allocation(state, max_migr_cost=100.0, time_limit=5.0)
    for kg, src, dst in plan.migrations:
        assert not state.kill[dst], f"kg {kg} moved {src}→{dst} (B!)"


def test_lemma2_drain_converges(cluster):
    """Lemma 2: repeated solving drains all key groups from B."""
    state = cluster.copy()
    state.kill[0] = True
    for _ in range(30):
        plan = solve_allocation(state, max_migr_cost=60.0, time_limit=5.0)
        state.alloc = plan.alloc
        if (state.alloc != 0).all():
            break
    assert (state.alloc != 0).all(), "node 0 not drained"


def test_dead_node_excluded(cluster):
    state = cluster.copy()
    state.alive[2] = False
    orphans = state.alloc == 2
    state.kg_state_bytes[orphans] = 0.0  # recovery from checkpoint
    plan = solve_allocation(state, time_limit=5.0)
    assert (plan.alloc != 2).all()


def test_heterogeneous_capacity(cluster):
    """A 2× node should receive ~2× the raw load of a 1× node."""
    state = cluster.copy()
    state.capacity = np.ones(state.num_nodes)
    state.capacity[0] = 2.0
    plan = solve_allocation(state, time_limit=5.0)
    raw = np.bincount(plan.alloc, weights=state.kg_load, minlength=state.num_nodes)
    assert raw[0] > raw[1:].mean() * 1.4


def test_multi_resource_constraint(cluster):
    """The multi-dimensional-load extension caps a second resource."""
    g = cluster.num_keygroups
    mem = np.ones(g)  # each key group uses 1 unit of memory
    caps = np.full(cluster.num_nodes, np.ceil(g / cluster.num_nodes) + 2)
    plan = solve_allocation(
        cluster, time_limit=5.0, extra_resources={"memory": (mem, caps)}
    )
    used = np.bincount(plan.alloc, weights=mem, minlength=cluster.num_nodes)
    assert (used <= caps + 1e-9).all()


def test_scale_out_rebalances():
    state = make_cluster(num_nodes=4, skew=True)
    grown = apply_scaling(state, ScalingDecision(add_nodes=2))
    plan = solve_allocation(grown, time_limit=5.0)
    assert len(np.unique(plan.alloc)) == 6  # new nodes actually used


# ----------------------------------------------------------------------------
# Property-based invariants
# ----------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nodes=st.integers(2, 6),
    kgs=st.integers(4, 16),
    budget=st.floats(0.0, 80.0),
)
def test_property_budget_and_assignment(seed, nodes, kgs, budget):
    state = make_cluster(num_nodes=nodes, kgs_per_op=kgs, num_ops=2, seed=seed)
    plan = solve_allocation(state, max_migr_cost=budget, time_limit=2.0)
    if plan.status == "infeasible":
        pytest.skip("solver budget infeasible for random instance")
    assert plan.migration_cost <= budget + 1e-6
    assert ((plan.alloc >= 0) & (plan.alloc < nodes)).all()
    # Never worse than doing nothing.
    assert plan.load_distance <= state.load_distance() + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_lemma1(seed):
    state = make_cluster(num_nodes=5, kgs_per_op=10, num_ops=2, seed=seed)
    state.kill[seed % 5] = True
    plan = solve_allocation(state, max_migr_cost=50.0, time_limit=2.0)
    if plan.status == "infeasible":
        pytest.skip("infeasible instance")
    for _, src, dst in plan.migrations:
        assert not state.kill[dst]

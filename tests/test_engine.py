"""Streaming engine end-to-end: execution, statistics, migration, elasticity,
failure recovery — the live substrate Algorithm 1 reconfigures."""

import numpy as np

from repro.core import AdaptationFramework, AlbicParams, UtilizationScaler
from repro.data import airline_stream, real_job_1, real_job_2
from repro.data.synthetic import StreamSpec, wiki_edit_stream
from repro.engine import Controller, ControllerConfig, Engine


def make_job2_engine(num_nodes=6, kgs=24, ser_cost=0.5, *, worst_alloc=True, seed=0):
    topo = real_job_2(keygroups_per_op=kgs)
    g = topo.num_keygroups
    alloc = np.zeros(g, dtype=np.int64)
    alloc[:kgs] = np.arange(kgs) % num_nodes
    alloc[kgs : 2 * kgs] = np.arange(kgs) % num_nodes
    shift = num_nodes // 2 if worst_alloc else 0
    alloc[2 * kgs :] = (np.arange(kgs) + shift) % num_nodes
    return Engine(
        topo,
        num_nodes,
        initial_alloc=alloc,
        ser_cost=ser_cost,
        service_rate=2000.0,
        seed=seed,
    )


def airline_feeder(rate=250.0, seed=0):
    stream = airline_stream(StreamSpec(rate=rate, seed=seed))

    def feeder(engine, tick):
        keys, values, ts = next(stream)
        engine.push_source("airline", keys, values, ts)

    return feeder


def test_engine_processes_and_measures():
    eng = make_job2_engine()
    feeder = airline_feeder()
    for t in range(10):
        feeder(eng, t)
        eng.tick()
    snap = eng.end_period()
    assert eng.metrics.processed_tuples > 1000
    assert snap.kg_load.sum() > 0
    assert snap.out_rates.sum() > 0
    # SumDelay actually computed sums (real operator semantics).
    sums = [
        s.get("sums")
        for _, s in eng.store.items()
        if "sums" in s
    ]
    assert sums and any(len(x) > 0 for x in sums)


def test_cross_node_traffic_charged():
    worst = make_job2_engine(worst_alloc=True)
    best = make_job2_engine(worst_alloc=False)
    feeder = airline_feeder()
    for engine in (worst, best):
        for t in range(10):
            feeder(engine, t)
            engine.tick()
    assert worst.metrics.cross_node_tuples > best.metrics.cross_node_tuples


def test_albic_controller_improves_collocation_and_load_index():
    """The Fig. 12 reproduction in miniature."""
    eng = make_job2_engine()
    ctl = Controller(
        eng,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=15.0, time_limit=2.0),
        ),
        ControllerConfig(ticks_per_period=10),
        feeder=airline_feeder(),
    )
    first = ctl.period()
    for _ in range(7):
        last = ctl.period()
    assert last.collocation_factor > first.collocation_factor + 10
    assert last.load_index < 95.0
    assert all(m.num_migrations <= 10 for m in ctl.history)


def test_milp_controller_balances_load():
    eng = make_job2_engine()
    ctl = Controller(
        eng,
        AdaptationFramework(mode="milp", max_migrations=13, time_limit=2.0),
        ControllerConfig(ticks_per_period=10),
        feeder=airline_feeder(seed=7),
    )
    for _ in range(5):
        m = ctl.period()
    assert m.load_distance < 15.0


def test_migration_preserves_state():
    """Direct state migration: σ_k arrives intact, buffered tuples replay."""
    eng = make_job2_engine()
    feeder = airline_feeder()
    for t in range(8):
        feeder(eng, t)
        eng.tick()
    # Pick a key group with state and migrate it by hand.
    kg = next(k for k, s in eng.store.items() if s.get("sums"))
    before = dict(eng.store.get(kg)["sums"])
    src = eng.router.node_of(kg)
    dst = (src + 1) % eng.num_nodes
    eng.redirect(kg, dst)
    feeder(eng, 99)  # traffic lands in the buffer meanwhile
    blob = eng.serialize(kg)
    eng.install(kg, dst, blob)
    assert eng.router.node_of(kg) == dst
    after = eng.store.get(kg)["sums"]
    for key, val in before.items():
        assert key in after and after[key] >= val - 1e-9
    # Replay: buffered batches were re-enqueued.
    for _ in range(5):
        eng.tick()
    assert not eng.router.in_flight


def test_scale_out_on_overload():
    topo = real_job_1(keygroups_per_op=20)
    eng = Engine(topo, 2, ser_cost=0.2, service_rate=500.0, seed=1)
    stream = wiki_edit_stream(StreamSpec(rate=400.0, seed=1))

    def feeder(engine, tick):
        keys, values, ts = next(stream)
        engine.push_source("wiki", keys, values, ts)

    ctl = Controller(
        eng,
        AdaptationFramework(
            scaler=UtilizationScaler(high_wm=60.0, target=40.0),
            mode="milp",
            max_migrations=20,
            time_limit=2.0,
        ),
        ControllerConfig(ticks_per_period=8),
        feeder=feeder,
    )
    for _ in range(6):
        m = ctl.period()
    assert eng.num_nodes > 2, "engine never scaled out under overload"


def test_node_failure_recovery():
    eng = make_job2_engine()
    feeder = airline_feeder(seed=3)
    ctl = Controller(
        eng,
        AdaptationFramework(mode="milp", max_migrations=10, time_limit=2.0),
        ControllerConfig(ticks_per_period=8),
        feeder=feeder,
    )
    ctl.period()
    snap = eng.end_period()
    # Run another period to have fresh stats, then kill node 1.
    for t in range(8):
        feeder(eng, t)
        eng.tick()
    snap = eng.end_period()
    victim = 1
    result = ctl.handle_node_failure(victim, snap)
    assert not eng.alive[victim]
    assert (eng.router.table != victim).all(), "orphans not reallocated"
    # Engine keeps processing afterwards.
    for t in range(5):
        feeder(eng, t)
        eng.tick()
    assert eng.metrics.processed_tuples > 0


def test_backpressure_throttles_sources():
    topo = real_job_1(keygroups_per_op=10)
    eng = Engine(topo, 1, service_rate=50.0, seed=2)  # tiny node
    stream = wiki_edit_stream(StreamSpec(rate=2000.0, seed=2))
    pushed = 0
    for t in range(30):
        keys, values, ts = next(stream)
        pushed += eng.push_source("wiki", keys, values, ts)
        eng.tick()
    assert eng.metrics.dropped_credits > 0, "no backpressure under overload"
    lat = eng.latency.summary()
    assert lat["p99"] > lat["p50"]

"""Engine data-plane throughput: end-to-end tuples/sec on a synthetic
multi-operator pipeline, plus MILP constraint-assembly time at the paper's
largest scale (Fig. 4: 60 nodes × 1200 key groups).

The pipeline job keeps operator bodies trivially cheap (a C-level re-key) so
the measurement isolates the engine hot path itself: key hashing, key-group
routing, queueing, and statistics recording.  The record-pipeline row runs
the same shape over structured record payloads twice — schema-typed
(columnar structured-array edges) versus the object path — so the columnar
win past the object-array boundary is pinned by its own number.  The
``pipeline_rec_jit`` row additionally runs the schema-typed shape through
the compiled tier (``ExecutionConfig.jit()``, one batched jax.jit call per
operator per tick): steady-state throughput is measured after a full
warm-up pass, with first-call trace+compile seconds reported separately in
the derived column.  The ``superstep_jit`` row runs the identical shape
through ``ExecutionConfig.superstep()`` + ``run_supersteps`` — route → drain → fn_jit
fused into a K-tick ``lax.scan``, one host crossing per scan — and derives
``vs_jit`` against the per-operator tier; ``radix_sort`` pins the routing
hot-path sort in isolation.  Repeated rows carry a ``spread=`` entry
(best/worst across repeats) so the perf gate can report noise alongside
the best-of-N estimate.  The ``push_source_ingest`` row pins the batched
ingestion boundary: structured-array stream batches convert in one C-level
call versus the per-tuple boxed-record representation.  The MILP row
reports assembly time separately from HiGHS solve time
(``total − solve_seconds``) so the constraint-build cost is pinned too.
"""

from __future__ import annotations

import functools
import time

import numpy as np

from benchmarks.common import bench_rng, bench_seed, csv_row, synthetic_cluster
from repro.core import solve_allocation
from repro.engine import Engine, ExecutionConfig, make_engine
from repro.engine.topology import (
    OperatorSpec,
    Schema,
    StateField,
    StateSchema,
    Topology,
)


def _rekey_stage(shift: int):
    """Near-zero-cost operator: re-key every tuple by an integer shift.

    Implements both operator protocols: the per-run ``fn`` (the engine's
    fallback for non-contiguous segments, and the oracle the equivalence
    tests pin ``fn_seg`` against) and the segment-vectorized ``fn_seg`` that
    updates every key group's state and re-keys the whole contiguous segment
    in one call.  Protocol lineage: the pre-PR-1 baseline used the
    list-of-tuples body, PR 1 the array-native ``fn``, PR 2 adds ``fn_seg``.
    """

    def fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        return state, (keys + shift, values, ts)

    def fn_seg(store, kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        return (keys + shift, values, ts), None  # output lengths == inputs

    return fn, fn_seg


def _counting_sink(state, keys, values, ts):
    state["n"] = state.get("n", 0) + len(keys)
    return state, []


def _counting_sink_seg(store, kgs, starts, ends, keys, values, ts):
    for kg, a, z in zip(kgs, starts, ends):
        st = store[kg]
        st["n"] = st.get("n", 0) + (z - a)
    return None, None


def make_pipeline_job(*, num_keygroups: int = 64, depth: int = 3) -> Topology:
    """source → depth−1 re-key stages → counting sink, all int-keyed."""
    t = Topology()
    t.add_operator(
        OperatorSpec("src", None, num_keygroups=num_keygroups, is_source=True)
    )
    prev = "src"
    for i in range(depth - 1):
        name = f"stage{i}"
        fn, fn_seg = _rekey_stage(17 * (i + 1))
        t.add_operator(
            OperatorSpec(name, fn, num_keygroups=num_keygroups, fn_seg=fn_seg)
        )
        t.connect(prev, name)
        prev = name
    t.add_operator(
        OperatorSpec(
            "sink",
            _counting_sink,
            num_keygroups=num_keygroups,
            is_sink=True,
            fn_seg=_counting_sink_seg,
        )
    )
    t.connect(prev, "sink")
    return t


def measure_pipeline(
    *,
    batch: int = 2048,
    ticks: int = 50,
    num_keygroups: int = 64,
    depth: int = 4,
    repeats: int = 3,
) -> tuple[float, float]:
    """Return (tuples/sec processed, µs per tick) on the pipeline job.

    Best of ``repeats`` fresh engines — the minimum-time estimator, robust to
    scheduler noise on shared hosts.
    """
    rng = bench_rng("engine_throughput", "measure_pipeline")
    keys = rng.integers(0, 1_000_000, size=batch).astype(np.int64)
    values = rng.random(batch)
    ts = np.zeros(batch)
    best = 0.0
    for _ in range(max(repeats, 1)):
        topo = make_pipeline_job(num_keygroups=num_keygroups, depth=depth)
        # collect_sinks=False: measure the data plane, not sink-list appends.
        eng = Engine(topo, num_nodes=8, service_rate=1e12,
                seed=bench_seed("engine_throughput", "alloc"),
                collect_sinks=False)
        # Warm up one tick (store/window allocation) outside the timed region.
        eng.push_source("src", keys, values, ts)
        eng.tick()
        start_processed = eng.metrics.processed_tuples
        t0 = time.perf_counter()
        for tick in range(ticks):
            eng.push_source("src", keys, values, ts + float(tick))
            eng.tick()
        dt = time.perf_counter() - t0
        processed = eng.metrics.processed_tuples - start_processed
        best = max(best, processed / dt)
    # src + (depth−1) stages + sink = depth+1 operators process each tuple.
    return best, batch * (depth + 1) / best * 1e6


_REC_SCHEMA = Schema.record([("a", "i8"), ("b", "f8")])
_COUNT_STATE = StateSchema((StateField("n", "scalar", dtype=np.int64, py=int),))


def _counting_sink_jit(state, kgs, starts, ends, keys, values, ts):
    from repro.engine import jitexec as jx

    return {"n": jx.count_runs(state["n"], kgs, starts, ends)}, None, None


@functools.lru_cache(maxsize=None)
def _record_stage(shift: int):
    """Record-payload stage: re-key and fold the int column into the float.

    The fn_seg body branches on the value representation: structured column
    arithmetic on the typed path, ``zip(*values)`` extraction on the object
    path — the same contract the real jobs follow.  The fn_jit body is the
    compiled-tier port (pure column math over the padded segment).
    Memoized so every topology instance shares one set of body objects —
    the jit compile cache is keyed by them.
    """

    def fn(state, keys, values, ts):
        state["n"] = state.get("n", 0) + len(keys)
        out = [
            (k, (v[0], v[1] + v[0]), t)
            for k, v, t in zip(keys.tolist(), values.tolist(), ts.tolist())
        ]
        return state, out

    def fn_seg(store, kgs, starts, ends, keys, values, ts):
        for kg, a, z in zip(kgs, starts, ends):
            st = store[kg]
            st["n"] = st.get("n", 0) + (z - a)
        if values.dtype.names is not None:
            out = np.empty(len(values), dtype=_REC_SCHEMA.value)
            out["a"] = values["a"]
            out["b"] = values["b"] + values["a"]
        else:
            a_l, b_l = zip(*values.tolist())
            a = np.asarray(a_l, dtype=np.int64)
            b = np.asarray(b_l) + a
            out = np.empty(len(values), dtype=object)
            out[:] = list(zip(a.tolist(), b.tolist()))
        return (keys + shift, out, ts), None

    def fn_jit(state, kgs, starts, ends, keys, values, ts):
        from repro.engine import jitexec as jx

        col = jx.count_runs(state["n"], kgs, starts, ends)
        out = {"a": values["a"], "b": values["b"] + values["a"]}
        return {"n": col}, (keys + shift, out, ts), None

    def key_map(keys):
        return keys + shift

    return fn, fn_seg, fn_jit, key_map


def _best_and_spread(rates: list[float]) -> tuple[float, float]:
    """Best-of-N estimator plus its spread (best/worst across repeats) —
    the spread rides along in the derived column so the perf gate can tell
    a noisy row from a real regression."""
    best = max(rates)
    return best, best / max(min(rates), 1e-9)


def make_record_pipeline_job(*, num_keygroups: int = 64, depth: int = 3) -> Topology:
    """source → depth−1 record stages → counting sink, schema-declared.

    Every stage implements all three protocols; ``ExecutionConfig.jit()``
    selects whether the compiled tier runs them.
    """
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "src",
            None,
            num_keygroups=num_keygroups,
            is_source=True,
            schema=_REC_SCHEMA,
        )
    )
    prev = "src"
    for i in range(depth - 1):
        name = f"stage{i}"
        fn, fn_seg, fn_jit, key_map = _record_stage(17 * (i + 1))
        t.add_operator(
            OperatorSpec(
                name,
                fn,
                num_keygroups=num_keygroups,
                fn_seg=fn_seg,
                fn_jit=fn_jit,
                jit_fusible=True,
                jit_key_map=key_map,
                state_schema=_COUNT_STATE,
                schema=_REC_SCHEMA,
                out_schema=_REC_SCHEMA,
            )
        )
        t.connect(prev, name)
        prev = name
    t.add_operator(
        OperatorSpec(
            "sink",
            _counting_sink,
            num_keygroups=num_keygroups,
            is_sink=True,
            fn_seg=_counting_sink_seg,
            fn_jit=_counting_sink_jit,
            jit_fusible=True,
            state_schema=_COUNT_STATE,
            schema=_REC_SCHEMA,
        )
    )
    t.connect(prev, "sink")
    return t


def measure_record_pipeline(
    *,
    batch: int = 2048,
    ticks: int = 50,
    num_keygroups: int = 64,
    depth: int = 4,
    repeats: int = 3,
) -> dict[str, float]:
    """Columnar vs object throughput on the record-payload pipeline."""
    rng = bench_rng("engine_throughput", "measure_record_pipeline")
    keys = rng.integers(0, 1_000_000, size=batch).astype(np.int64)
    values = list(zip(rng.integers(0, 1_000, size=batch).tolist(), rng.random(batch)))
    ts = np.zeros(batch)
    out = {}
    for label, use_schema in (("typed", True), ("obj", False)):
        best = 0.0
        for _ in range(max(repeats, 1)):
            topo = make_record_pipeline_job(num_keygroups=num_keygroups, depth=depth)
            eng = Engine(
                topo,
                num_nodes=8,
                service_rate=1e12,
                seed=bench_seed("engine_throughput", "alloc"),
                collect_sinks=False,
                config=ExecutionConfig(use_schema=use_schema),
            )
            eng.push_source("src", keys, values, ts)
            eng.tick()
            start = eng.metrics.processed_tuples
            t0 = time.perf_counter()
            for tick in range(ticks):
                eng.push_source("src", keys, values, ts + float(tick))
                eng.tick()
            dt = time.perf_counter() - t0
            best = max(best, (eng.metrics.processed_tuples - start) / dt)
        out[label] = best
    out["speedup"] = out["typed"] / max(out["obj"], 1e-9)
    out["us_per_tick"] = batch * (depth + 1) / out["typed"] * 1e6
    return out


def _record_batch(batch: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    rng = bench_rng("engine_throughput", "_record_batch")
    keys = rng.integers(0, 1_000_000, size=batch).astype(np.int64)
    values = np.empty(batch, dtype=_REC_SCHEMA.value)
    values["a"] = rng.integers(0, 1_000, size=batch)
    values["b"] = rng.random(batch)
    return keys, values, np.zeros(batch)


def measure_record_pipeline_jit(
    *,
    batch: int = 8192,
    ticks: int = 20,
    num_keygroups: int = 64,
    depth: int = 4,
    repeats: int = 3,
) -> dict[str, float]:
    """Compiled tier vs numpy fn_seg on the record pipeline.

    Both paths run the identical schema-typed engine configuration; the jit
    engine takes one warm-up pass over every tick first (all padding
    buckets compile there), so the timed pass measures steady state —
    first-call trace+compile seconds are reported separately.
    """
    keys, values, ts = _record_batch(batch)
    out: dict[str, float] = {}
    for label, use_jit in (("jit", True), ("seg", False)):
        rates: list[float] = []
        for _ in range(max(repeats, 1)):
            topo = make_record_pipeline_job(
                num_keygroups=num_keygroups, depth=depth
            )
            eng = Engine(
                topo,
                num_nodes=8,
                service_rate=1e12,
                seed=bench_seed("engine_throughput", "alloc"),
                collect_sinks=False,
                config=ExecutionConfig.jit() if use_jit else ExecutionConfig.typed(),
            )
            for tick in range(ticks):  # warm-up: compiles + allocation
                eng.push_source("src", keys, values, ts + float(tick))
                eng.tick()
            start = eng.metrics.processed_tuples
            t0 = time.perf_counter()
            for tick in range(ticks):
                eng.push_source("src", keys, values, ts + float(tick))
                eng.tick()
            dt = time.perf_counter() - t0
            rates.append((eng.metrics.processed_tuples - start) / dt)
            if use_jit and eng._jit is not None:
                # First repeat carries the real compiles; later repeats hit
                # the process-wide cache.
                out["compile_s"] = max(
                    out.get("compile_s", 0.0), eng._jit.compile_seconds
                )
        out[label], spread = _best_and_spread(rates)
        if use_jit:
            out["spread"] = spread
    out["jit_vs_seg"] = out["jit"] / max(out["seg"], 1e-9)
    out["us_per_tick"] = batch * (depth + 1) / out["jit"] * 1e6
    return out


def measure_superstep_jit(
    *,
    batch: int = 8192,
    ticks: int = 20,
    num_keygroups: int = 64,
    depth: int = 4,
    repeats: int = 3,
) -> dict[str, float]:
    """Device-resident superstep (``Engine.run_supersteps``): K fused ticks
    in one ``lax.scan``, one host↔device crossing per scan.

    Same topology, batch and tick count as :func:`measure_record_pipeline_jit`
    so the derived ``vs_jit`` ratio isolates what fusion buys over the
    per-operator compiled tier.  Each repeat warms up with one full scan
    (trace + compile) and drains before the timed scan; the timed region
    includes the host-side staging (typed conversion, hash, radix sort) —
    the real ingest cost of the fused path.
    """
    keys, values, ts = _record_batch(batch)
    batches = [(keys, values, ts + float(t)) for t in range(ticks)]
    out: dict[str, float] = {}
    rates: list[float] = []
    for _ in range(max(repeats, 1)):
        topo = make_record_pipeline_job(
            num_keygroups=num_keygroups, depth=depth
        )
        eng = Engine(
            topo,
            num_nodes=8,
            service_rate=1e12,
            seed=bench_seed("engine_throughput", "alloc"),
            collect_sinks=False,
            config=ExecutionConfig.superstep(),
        )
        eng.run_supersteps(batches)  # warm-up scan: compiles
        while any(bool(q) for q in eng._queues):
            eng.tick()
        start = eng.metrics.processed_tuples
        syncs0 = eng.metrics.jit_host_syncs
        t0 = time.perf_counter()
        eng.run_supersteps(batches)
        dt = time.perf_counter() - t0
        rates.append((eng.metrics.processed_tuples - start) / dt)
        out["host_syncs"] = float(eng.metrics.jit_host_syncs - syncs0)
        if eng._jit is not None:
            out["compile_s"] = max(
                out.get("compile_s", 0.0), eng._jit.compile_seconds
            )
    out["tps"], out["spread"] = _best_and_spread(rates)
    out["us_per_tick"] = batch * (depth + 1) / out["tps"] * 1e6
    return out


def measure_radix_sort(
    *, n: int = 1 << 15, buckets: int = 512, repeats: int = 5, loops: int = 30
) -> dict[str, float]:
    """The routing hot-path sort: bucketed stable radix argsort vs numpy.

    Sorts the (node × key group) composite exactly as ``_route_batch``
    builds it (int16 when the bucket space fits, the benchmark scale).  On
    CPU the dispatcher's reference path IS numpy's stable argsort, so the
    ratio pins dispatch overhead ≈ 1.0; on TPU the Pallas kernel takes over
    and the same row measures it.
    """
    from repro.kernels.radix_sort import bucket_argsort

    rng = bench_rng("engine_throughput", "measure_radix_sort")
    comp = rng.integers(0, buckets, size=n).astype(np.int16)
    out: dict[str, float] = {}
    for label, fn in (
        ("radix", lambda: bucket_argsort(comp, buckets)),
        ("numpy", lambda: np.argsort(comp, kind="stable")),
    ):
        rates = []
        fn()  # warm-up (dispatch caches, page-in)
        for _ in range(max(repeats, 1)):
            t0 = time.perf_counter()
            for _ in range(loops):
                fn()
            dt = time.perf_counter() - t0
            rates.append(loops / dt)
        best, spread = _best_and_spread(rates)
        out[label] = 1e6 / best  # µs per sort
        if label == "radix":
            out["spread"] = spread
    out["vs_numpy"] = out["numpy"] / max(out["radix"], 1e-9)
    return out


def measure_push_source_ingest(
    *, batch: int = 4096, pushes: int = 60, repeats: int = 3
) -> dict[str, float]:
    """Ingestion-conversion throughput of ``push_source`` on a typed source.

    ``typed`` feeds the structured-array batches the vectorized stream
    generators now emit (the declared-dtype buffer passes straight
    through); ``boxed`` feeds the identical data as the pre-PR list of
    python record tuples (one C-level ``np.array(list)`` conversion per
    push, after per-tuple boxing upstream).  Same engine, same routing —
    the delta is the ingestion boundary.
    """
    keys, values, ts = _record_batch(batch)
    boxed = values.tolist()
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "src", None, num_keygroups=64, is_source=True, schema=_REC_SCHEMA
        )
    )
    t.add_operator(
        OperatorSpec(
            "sink",
            _counting_sink,
            num_keygroups=64,
            is_sink=True,
            fn_seg=_counting_sink_seg,
            schema=_REC_SCHEMA,
        )
    )
    t.connect("src", "sink")
    out: dict[str, float] = {}
    for label, payload in (("typed", values), ("boxed", boxed)):
        best = 0.0
        for _ in range(max(repeats, 1)):
            eng = Engine(
                t, num_nodes=4, service_rate=1e12,
                seed=bench_seed("engine_throughput", "alloc"),
                collect_sinks=False
            )
            eng.push_source("src", keys, payload, ts)
            eng.tick()  # drain the warm-up push
            t0 = time.perf_counter()
            for i in range(pushes):
                eng.push_source("src", keys, payload, ts)
                if i % 8 == 7:
                    eng.tick()  # keep queues bounded, off the hot loop
            dt = time.perf_counter() - t0
            best = max(best, pushes * batch / dt)
        out[label] = best
    out["speedup"] = out["typed"] / max(out["boxed"], 1e-9)
    out["us_per_push"] = batch / out["typed"] * 1e6
    return out


def measure_multiworker(
    *,
    batch: int = 4096,
    ticks: int = 12,
    workers: tuple = (2, 4),
    num_keygroups: int = 64,
    depth: int = 4,
    repeats: int = 2,
) -> dict[str, float]:
    """Multi-worker host runtime vs the single-process typed engine.

    The identical schema-declared record pipeline streams the same batches
    through ``ExecutionConfig.typed()`` (lockstep push + tick) and through
    ``make_engine(..., ExecutionConfig.workers(n)).run_stream`` (pipelined
    ingestion over real OS worker processes).  Tuples/sec is end to end —
    ingest through full drain — so worker forking aside, the coordinator
    exchange, the report merge and the credit loop all sit inside the
    measurement.  ``w{n}_vs_single`` is the headline: >1 means the extra
    processes beat the serialization they pay for on this host.
    """
    rng = bench_rng("engine_throughput", "measure_multiworker")
    values = np.empty(batch, dtype=_REC_SCHEMA.value)
    values["a"] = rng.integers(0, 1_000, size=batch)
    values["b"] = rng.random(batch)
    batches = [
        (
            rng.integers(0, 1_000_000, size=batch).astype(np.int64),
            values,
            np.full(batch, float(t)),
        )
        for t in range(ticks)
    ]
    total = batch * ticks

    def single() -> float:
        eng = make_engine(
            make_record_pipeline_job(num_keygroups=num_keygroups, depth=depth),
            8,
            config=ExecutionConfig.typed(),
            service_rate=1e12,
            seed=bench_seed("engine_throughput", "alloc"),
            collect_sinks=False,
        )
        eng.push_source("src", *batches[0])  # warm-up: store/window alloc
        eng.tick()
        t0 = time.perf_counter()
        for b in batches:
            eng.push_source("src", *b)
            eng.tick()
        while any(bool(q) for q in eng._queues):
            eng.tick()
        return total / (time.perf_counter() - t0)

    def multi(n: int, shm: int | None = None) -> tuple[float, dict]:
        config = (
            ExecutionConfig.workers(n)
            if shm is None
            else ExecutionConfig.workers(n, shm=shm)
        )
        eng = make_engine(
            make_record_pipeline_job(num_keygroups=num_keygroups, depth=depth),
            8,
            config=config,
            service_rate=1e12,
            seed=bench_seed("engine_throughput", "alloc"),
            collect_sinks=False,
        )
        try:
            eng.run_stream("src", batches[:1], window=2 * n)  # warm-up
            while eng.worst_queue_cost() > 0.0:
                eng.tick()
            t0 = time.perf_counter()
            eng.run_stream("src", batches, window=2 * n)
            while eng.worst_queue_cost() > 0.0:
                eng.tick()
            rate = total / (time.perf_counter() - t0)
            eng.finalize()  # folds per-worker exchange counters
            return rate, dict(eng.exchange_stats)
        finally:
            eng.close()

    def xchg_us_per_tick(xs: dict, n: int) -> tuple[float, float]:
        """(exchange encode+decode µs per tick, exchanged ticks).

        Every worker sends one exchange message per peer per tick (shm or
        queue), so messages / (n·(n-1)) is exactly the tick count the
        counters span — warm-up and drain ticks included on both sides.
        """
        lanes = max(n * (n - 1), 1)
        nticks = (xs["shm_msgs"] + xs["queue_msgs"]) / lanes
        return (xs["enc_s"] + xs["dec_s"]) / max(nticks, 1e-9) * 1e6, nticks

    out: dict[str, float] = {}
    single_rates = [single() for _ in range(max(repeats, 1))]
    out["single"], out["spread"] = _best_and_spread(single_rates)
    first_xs: dict = {}
    for n in workers:
        runs = [multi(n) for _ in range(max(repeats, 1))]
        rate, xs = max(runs, key=lambda rx: rx[0])
        if n == workers[0]:
            first_xs = xs
        out[f"w{n}"] = rate
        out[f"w{n}_vs_single"] = out[f"w{n}"] / max(out["single"], 1e-9)
    # Exchange transport columns: per-tick encode+decode cost of the shm
    # lanes vs the same workload forced onto the pickled-queue fallback
    # (shm=0), plus bytes moved through the rings per tick.
    n0 = workers[0]
    out["xchg_us_per_tick"], nticks = xchg_us_per_tick(first_xs, n0)
    out["xchg_kb_per_tick"] = first_xs.get("shm_bytes_out", 0) / max(
        nticks, 1e-9
    ) / 1024.0
    queue_runs = [multi(n0, shm=0) for _ in range(max(repeats, 1))]
    out["xchg_queue_us_per_tick"] = min(
        xchg_us_per_tick(xs, n0)[0] for _, xs in queue_runs
    )
    out["xchg_speedup"] = out["xchg_queue_us_per_tick"] / max(
        out["xchg_us_per_tick"], 1e-9
    )
    # Primary gate metric: µs per tick of the first multi-worker variant,
    # end to end (total tuples / its tuples-per-second, per tick).
    out["us_per_tick"] = total / max(out[f"w{workers[0]}"], 1e-9) / ticks * 1e6
    return out


def measure_milp_assembly(
    *, nodes: int = 60, kgs: int = 1200, ops: int = 30, time_limit: float = 1.0
) -> tuple[float, float, str]:
    """Return (assembly seconds, solve seconds, status) at the Fig. 4 scale."""
    state = synthetic_cluster(
        nodes, kgs, ops, varies=20.0, seed=bench_seed("engine_throughput", "milp")
    )
    t0 = time.perf_counter()
    plan = solve_allocation(state, max_migrations=20, time_limit=time_limit)
    total = time.perf_counter() - t0
    return total - plan.solve_seconds, plan.solve_seconds, plan.status


def run(quick: bool = False) -> list[str]:
    rows = []
    batch = 512 if quick else 2048
    ticks = 15 if quick else 50
    tps, us_tick = measure_pipeline(batch=batch, ticks=ticks)
    rows.append(
        csv_row(
            f"engine_throughput/pipeline_d4_64kg_b{batch}",
            us_tick,
            f"tuples_per_sec={tps:.0f}",
        )
    )
    rec = measure_record_pipeline(batch=batch, ticks=ticks)
    rows.append(
        csv_row(
            f"engine_throughput/pipeline_rec_d4_64kg_b{batch}",
            rec["us_per_tick"],
            f"tuples_per_sec={rec['typed']:.0f}"
            f";object_tuples_per_sec={rec['obj']:.0f}"
            f";columnar_vs_object={rec['speedup']:.2f}",
        )
    )
    jit_batch = 4096 if quick else 8192
    jit_ticks = 10 if quick else 20
    jrec = measure_record_pipeline_jit(batch=jit_batch, ticks=jit_ticks)
    rows.append(
        csv_row(
            f"engine_throughput/pipeline_rec_jit_b{jit_batch}",
            jrec["us_per_tick"],
            f"tuples_per_sec={jrec['jit']:.0f}"
            f";seg_tuples_per_sec={jrec['seg']:.0f}"
            f";jit_vs_seg={jrec['jit_vs_seg']:.2f}"
            f";compile_s={jrec.get('compile_s', 0.0):.2f}"
            f";spread={jrec['spread']:.2f}",
        )
    )
    sup = measure_superstep_jit(batch=jit_batch, ticks=jit_ticks)
    rows.append(
        csv_row(
            "engine_throughput/superstep_jit",
            sup["us_per_tick"],
            f"tuples_per_sec={sup['tps']:.0f}"
            f";vs_jit={sup['tps'] / max(jrec['jit'], 1e-9):.2f}"
            f";host_syncs_per_scan={sup['host_syncs']:.0f}"
            f";compile_s={sup.get('compile_s', 0.0):.2f}"
            f";spread={sup['spread']:.2f}",
        )
    )
    rs = measure_radix_sort(n=1 << 14 if quick else 1 << 15)
    rows.append(
        csv_row(
            "engine_throughput/radix_sort",
            rs["radix"],
            f"numpy_us={rs['numpy']:.1f}"
            f";vs_numpy={rs['vs_numpy']:.2f}"
            f";spread={rs['spread']:.2f}",
        )
    )
    ing = measure_push_source_ingest(
        batch=2048 if quick else 4096, pushes=40 if quick else 60
    )
    rows.append(
        csv_row(
            "engine_throughput/push_source_ingest",
            ing["us_per_push"],
            f"tuples_per_sec={ing['typed']:.0f}"
            f";boxed_tuples_per_sec={ing['boxed']:.0f}"
            f";typed_vs_boxed={ing['speedup']:.2f}",
        )
    )
    mw = measure_multiworker(
        batch=2048 if quick else 4096, ticks=8 if quick else 12
    )
    rows.append(
        csv_row(
            "engine_throughput/multiworker",
            mw["us_per_tick"],
            f"single_tuples_per_sec={mw['single']:.0f}"
            f";w2_tuples_per_sec={mw['w2']:.0f}"
            f";w4_tuples_per_sec={mw['w4']:.0f}"
            f";w2_vs_single={mw['w2_vs_single']:.2f}"
            f";w4_vs_single={mw['w4_vs_single']:.2f}"
            f";xchg_us_per_tick={mw['xchg_us_per_tick']:.1f}"
            f";xchg_queue_us_per_tick={mw['xchg_queue_us_per_tick']:.1f}"
            f";xchg_speedup={mw['xchg_speedup']:.2f}"
            f";xchg_kb_per_tick={mw['xchg_kb_per_tick']:.1f}"
            f";spread={mw['spread']:.2f}",
        )
    )
    assembly, solve, status = measure_milp_assembly(time_limit=0.5 if quick else 1.0)
    rows.append(
        csv_row(
            "engine_throughput/milp_assembly_60x1200",
            assembly * 1e6,
            f"solve={solve:.2f}s;status={status}",
        )
    )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

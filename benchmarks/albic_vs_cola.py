"""Figs 10–11: ALBIC vs COLA on the §5.3 synthetic workload.

Fig 10: 40 nodes / 800 kgs / 20 ops, maxMigrations = 20, max obtainable
collocation swept 0–100%.  Fig 11: collocation fixed at 50%, three cluster
sizes.  Per solve, 20% of nodes drift ±2% (paper setting)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row, drift_loads, synthetic_cluster
from repro.core import AlbicParams, albic
from repro.core.baselines import cola_allocate


def episode(state, method: str, iters: int, seed: int):
    rng = np.random.default_rng(seed)
    lds, cols, migs = [], [], []
    for i in range(iters):
        drift_loads(state, 2.0, rng)
        if method == "albic":
            res = albic(
                state,
                max_migrations=20,
                params=AlbicParams(max_ld=10.0, time_limit=2.0, seed=seed + i),
            )
            plan = res.plan
        else:
            plan = cola_allocate(state, seed=seed + i)
        state = state.copy()
        state.alloc = plan.alloc
        lds.append(state.load_distance())
        cols.append(state.collocation_factor())
        migs.append(plan.num_migrations)
    return np.mean(lds[1:]), cols[-1], np.mean(migs[1:])


def run(quick: bool = False) -> list[str]:
    rows = []
    iters = 3 if quick else 4
    # Fig 10: sweep max obtainable collocation.
    sweep = [0, 50, 100] if quick else [0, 25, 50, 100]
    nodes, kgs, ops = (20, 400, 10) if quick else (40, 800, 20)
    for pct in sweep:
        for method in ("albic", "cola"):
            state = synthetic_cluster(
                nodes,
                kgs,
                ops,
                one_to_one_pct=pct,
                seed=bench_seed("albic_vs_cola", "fig10"),
            )
            t0 = time.perf_counter()
            ld, col, mig = episode(state, method, iters, seed=pct)
            dt = (time.perf_counter() - t0) / iters
            rows.append(
                csv_row(
                    f"albic_vs_cola/fig10/colloc{pct}/{method}",
                    dt * 1e6,
                    f"ld={ld:.2f};collocation={col:.1f};migrations={mig:.0f}",
                )
            )
    # Fig 11: three cluster configurations at 50% collocation.
    configs = [(20, 400, 10)] if quick else [
        (20, 400, 10),
        (40, 800, 20),
        (60, 1200, 30),
    ]
    for n, g, o in configs:
        for method in ("albic", "cola"):
            state = synthetic_cluster(
                n, g, o, one_to_one_pct=50, seed=bench_seed("albic_vs_cola", "fig11")
            )
            t0 = time.perf_counter()
            ld, col, mig = episode(state, method, iters, seed=n)
            dt = (time.perf_counter() - t0) / iters
            rows.append(
                csv_row(
                    f"albic_vs_cola/fig11/{n}n_{g}kg/{method}",
                    dt * 1e6,
                    f"ld={ld:.2f};collocation={col:.1f};migrations={mig:.0f}",
                )
            )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

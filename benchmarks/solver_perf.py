"""Figs 2–4: MILP solve time vs solution quality, three cluster sizes,
compared against Flux at equal migration budgets."""

from __future__ import annotations

import time



from benchmarks.common import bench_seed, csv_row, synthetic_cluster
from repro.core import solve_allocation
from repro.core.baselines import flux_rebalance

CONFIGS = [
    ("fig2_20n_400kg", 20, 400, 10),
    ("fig3_40n_800kg", 40, 800, 20),
    ("fig4_60n_1200kg", 60, 1200, 30),
]


def run(quick: bool = False) -> list[str]:
    rows = []
    configs = CONFIGS[:2] if quick else CONFIGS
    budgets = [20] if quick else [10, 20]
    time_limits = [2.0] if quick else [1.0, 4.0]
    for name, nodes, kgs, ops in configs:
        for varies in ([20.0] if quick else [10.0, 20.0]):
            state = synthetic_cluster(
                nodes, kgs, ops, varies=varies, seed=bench_seed("solver_perf", name)
            )
            base_ld = state.load_distance()
            for budget in budgets:
                flux = flux_rebalance(state, max_migrations=budget)
                for tl in time_limits:
                    t0 = time.perf_counter()
                    plan = solve_allocation(
                        state, max_migrations=budget, time_limit=tl
                    )
                    dt = time.perf_counter() - t0
                    rows.append(
                        csv_row(
                            f"solver_perf/{name}/v{varies:.0f}/m{budget}/t{tl:.0f}s",
                            dt * 1e6,
                            f"milp_ld={plan.load_distance:.2f};flux_ld={flux.load_distance:.2f};"
                            f"base_ld={base_ld:.2f};status={plan.status}",
                        )
                    )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks problem
sizes for CI-style runs; the full run reproduces the paper's configurations.
"""

from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    "solver_perf",          # Figs 2–4
    "engine_throughput",    # data-plane tuples/sec + MILP assembly time
    "integrated_scaling",   # Fig 5
    "milp_vs_flux_potc",    # Figs 6–7
    "unrestricted",         # Figs 8–9
    "albic_vs_cola",        # Figs 10–11
    "real_jobs",            # Figs 12–14
    "roofline_bench",       # dry-run roofline table (this build)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            for row in mod.run(quick=args.quick):
                print(row, flush=True)
        except Exception as e:  # keep the harness going; record the failure
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{str(e)[:120]}", flush=True)
        print(
            f"# {name} finished in {time.perf_counter()-t0:.1f}s",
            file=sys.stderr,
            flush=True,
        )
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

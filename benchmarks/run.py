"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks problem
sizes for CI-style runs; the full run reproduces the paper's configurations.
``--json PATH`` additionally writes the rows as a JSON document (the format
``benchmarks/compare.py`` consumes for the CI perf-regression gate).
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time


MODULES = [
    "solver_perf",          # Figs 2–4
    "engine_throughput",    # data-plane tuples/sec + MILP assembly time
    "integrated_scaling",   # Fig 5
    "milp_vs_flux_potc",    # Figs 6–7
    "unrestricted",         # Figs 8–9
    "albic_vs_cola",        # Figs 10–11
    "real_jobs",            # Figs 12–14
    "skew_grid",            # skew scenarios × mitigation strategies
    "fault_recovery",       # MTTR + tuple loss/duplication under faults
    "roofline_bench",       # dry-run roofline table (this build)
]


def parse_row(row: str) -> dict:
    """``name,us_per_call,derived`` → {"name", "us_per_call", "derived"}."""
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def git_sha() -> str:
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True,
                text=True,
                timeout=10,
            ).stdout.strip()
            or "unknown"
        )
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    ap.add_argument("--json", default=None, help="also write rows as JSON to PATH")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    rows: list[dict] = []
    failures = 0
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            for row in mod.run(quick=args.quick):
                print(row, flush=True)
                rows.append(parse_row(row))
        except Exception as e:  # keep the harness going; record the failure
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{str(e)[:120]}", flush=True)
        print(
            f"# {name} finished in {time.perf_counter()-t0:.1f}s",
            file=sys.stderr,
            flush=True,
        )
    if args.json:
        doc = {
            "schema": 1,
            "sha": git_sha(),
            "quick": args.quick,
            "modules": names,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Shared benchmark scaffolding: the paper's §5.1 synthetic cluster generator,
the root-seed derivation every benchmark workload threads through, and small
reporting helpers."""

from __future__ import annotations

import os
import zlib

import numpy as np

from repro.core.stats import ClusterState

#: The single root seed all benchmark randomness derives from.  Override per
#: run with ``REPRO_BENCH_SEED=<int>`` to reshape every workload coherently —
#: engine allocations, synthetic clusters, and generated streams all shift
#: together, so "does the result hold on another seed?" is one environment
#: variable instead of a dozen scattered literals.  The committed
#: ``baseline.json`` was measured at the default.
ROOT_SEED = 0


def root_seed() -> int:
    return int(os.environ.get("REPRO_BENCH_SEED", ROOT_SEED))


def bench_seed(*salt) -> int:
    """A stable per-site seed derived from the root seed and a salt path.

    ``bench_seed("milp_vs_flux_potc", "build")`` names the call site; equal
    salts always derive the same seed for a given root, and any root change
    moves every site at once.  Salts hash through crc32, so strings and
    numbers mix freely and the derivation is stable across processes and
    platforms (no PYTHONHASHSEED dependence).
    """
    parts = [zlib.crc32(str(s).encode()) for s in salt]
    ss = np.random.SeedSequence([root_seed(), *parts])
    return int(ss.generate_state(1)[0])


def bench_rng(*salt) -> np.random.Generator:
    """``np.random.default_rng`` over :func:`bench_seed` (same salt rules)."""
    return np.random.default_rng(bench_seed(*salt))


def synthetic_cluster(
    num_nodes: int,
    num_keygroups: int,
    num_ops: int,
    *,
    varies: float = 20.0,
    one_to_one_pct: float = 0.0,
    seed: int = 0,
) -> ClusterState:
    """Paper §5.1: even allocation; each key group at mean ± 5%; then 20% of
    the nodes get ±varies/2 load adjustments.  §5.3 adds x% 1-1 pairs."""
    rng = np.random.default_rng(seed)
    kg_per_op = num_keygroups // num_ops
    kg_op = np.repeat(np.arange(num_ops), kg_per_op)
    alloc = np.arange(num_keygroups) % num_nodes

    mean_load = 60.0 / (num_keygroups / num_nodes)  # ~60% node utilization
    load = mean_load * rng.uniform(0.95, 1.05, num_keygroups)

    # Adjust 20% of nodes by ±varies/2 (%) via their key groups.
    n_adj = max(int(0.2 * num_nodes), 2)
    adjusted = rng.choice(num_nodes, size=n_adj, replace=False)
    for i, node in enumerate(adjusted):
        sign = +1.0 if i < n_adj // 2 else -1.0
        kgs = np.where(alloc == node)[0]
        load[kgs] *= 1.0 + sign * (varies / 2.0) / 100.0

    out = np.zeros((num_keygroups, num_keygroups))
    n11 = int(kg_per_op * one_to_one_pct / 100.0)
    for op in range(num_ops - 1):
        base, nxt = op * kg_per_op, (op + 1) * kg_per_op
        for i in range(n11):
            out[base + i, nxt + i] = rng.uniform(5, 15)
        for i in range(n11, kg_per_op):
            out[base + i, nxt : nxt + kg_per_op] = rng.uniform(0.02, 0.08, kg_per_op)
    downstream = {i: [i + 1] for i in range(num_ops - 1)}
    downstream[num_ops - 1] = []
    return ClusterState.create(
        num_nodes,
        kg_op,
        load,
        alloc,
        kg_state_bytes=rng.uniform(1, 10, num_keygroups),
        out_rates=out,
        downstream=downstream,
    )


def drift_loads(state: ClusterState, pct: float, rng: np.random.Generator) -> None:
    """§5.3: adjust the load of 20% of nodes by ±pct% between solves."""
    nodes = rng.choice(
        state.num_nodes,
        size=max(state.num_nodes // 5, 1),
        replace=False,
    )
    for node in nodes:
        kgs = np.where(state.alloc == node)[0]
        state.kg_load[kgs] *= 1.0 + rng.uniform(-pct, pct) / 100.0


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

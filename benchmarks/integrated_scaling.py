"""Fig 5: integrated vs non-integrated scale-in (1OL / 5OL).

Largest §5.1 cluster; 10 nodes marked for removal; maxMigrations = 20.  The
integrated MILP prioritizes urgent rebalancing against draining inside one
program; the non-integrated baseline first drains B round-robin (budget
permitting), then balances what is left.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row, synthetic_cluster
from repro.core import solve_allocation

BUDGET = 20


def overload(state, n_nodes: int) -> None:
    """Set n nodes to 100% load (the paper's 1OL / 5OL settings)."""
    for node in range(2, 2 + n_nodes):
        kgs = np.where(state.alloc == node)[0]
        state.kg_load[kgs] *= 100.0 / max(state.node_loads()[node], 1e-9)


def run_integrated(state, rounds: int):
    ld_path, drained_at = [], None
    for r in range(rounds):
        plan = solve_allocation(state, max_migrations=BUDGET, time_limit=3.0)
        state = state.copy()
        state.alloc = plan.alloc
        ld_path.append(state.load_distance())
        if drained_at is None and not np.isin(state.alloc, state.nodes_b).any():
            drained_at = r + 1
    return ld_path, drained_at


def run_non_integrated(state, rounds: int):
    """Drain-first baseline: move B's key groups round-robin, then balance."""
    ld_path, drained_at = [], None
    for r in range(rounds):
        state = state.copy()
        b_nodes = set(state.nodes_b.tolist())
        moves = 0
        targets = list(state.nodes_a)
        ti = 0
        for kg in np.where(np.isin(state.alloc, list(b_nodes)))[0]:
            if moves >= BUDGET:
                break
            state.alloc[kg] = targets[ti % len(targets)]
            ti += 1
            moves += 1
        if moves < BUDGET:  # leftover budget → independent balancing
            plan = solve_allocation(
                state, max_migrations=BUDGET - moves, time_limit=3.0
            )
            state.alloc = plan.alloc
        ld_path.append(state.load_distance())
        if drained_at is None and not np.isin(state.alloc, list(b_nodes)).any():
            drained_at = r + 1
    return ld_path, drained_at


def run(quick: bool = False) -> list[str]:
    rows = []
    nodes, kgs, ops = (40, 800, 20) if quick else (60, 1200, 30)
    rounds = 8 if quick else 14
    marked = 5 if quick else 10
    for n_ol, tag in [(1, "1OL"), (5, "5OL")]:
        state = synthetic_cluster(
            nodes, kgs, ops, seed=bench_seed("integrated_scaling", tag)
        )
        overload(state, n_ol)
        state.kill[-marked:] = True  # mark nodes for removal
        t0 = time.perf_counter()
        ld_i, drain_i = run_integrated(state.copy(), rounds)
        t_int = time.perf_counter() - t0
        t0 = time.perf_counter()
        ld_n, drain_n = run_non_integrated(state.copy(), rounds)
        t_non = time.perf_counter() - t0
        rows.append(
            csv_row(
                f"integrated_scaling/{tag}/integrated",
                t_int / rounds * 1e6,
                f"ld_path={['%.1f' % x for x in ld_i]};drained_round={drain_i}",
            )
        )
        rows.append(
            csv_row(
                f"integrated_scaling/{tag}/non_integrated",
                t_non / rounds * 1e6,
                f"ld_path={['%.1f' % x for x in ld_n]};drained_round={drain_n}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

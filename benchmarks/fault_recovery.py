"""Fault recovery: MTTR and tuple loss/duplication under deterministic faults.

Each scenario runs the self-healing cluster runtime (2 workers, shm lanes,
``CheckpointPolicy(every=2)`` + supervision) over a fixed batch schedule with
a seeded :class:`~repro.engine.faults.FaultPlan`, then replays the identical
schedule fault-free as the reference:

``kill_mid_stream``   SIGKILL one worker mid-period, after the first
                      checkpoint committed — the canonical unattended
                      recovery: detect death, respawn, rewind to the
                      checkpoint, replay buffered admissions.
``hang_escalation``   wedge one worker mid-command instead; the supervisor
                      must first *decide* the worker is wedged (the
                      liveness deadline, reported as ``deadline_ms``) and
                      SIGKILL it — MTTR then measures the same heal path
                      from that detection onward.

Derived metrics per row:

``mttr_ms``       best-of-N mean-time-to-repair (death detection → cluster
                  serving again, from ``RecoveryReport.mttr_s``) — gated:
                  a regression means recovery itself got slower
``tuples_lost``   reference sink tuples missing from the healed run (the
                  loss bound: tuples queued in flight at the crash — the
                  checkpoint does not capture them, replay only covers
                  admissions after the cut)
``tuples_dup``    healed sink tuples beyond the reference multiset (sinks
                  emitted between the checkpoint cut and the crash are
                  re-emitted by replay: recovery is at-least-once)
``recoveries``    supervised recoveries completed (sanity: exactly 1)

Loss/duplication are multiset differences, so reordering from post-recovery
scheduling never counts as loss.  ``us_per_call`` is wall time per driven
tick of the healed run; ``spread=`` is worst/best MTTR across repeats.
"""

from __future__ import annotations

import collections
import tempfile
import time

import numpy as np

from benchmarks.common import bench_rng, csv_row
from repro.engine import ExecutionConfig, make_engine
from repro.engine.config import CheckpointPolicy, SupervisionPolicy
from repro.engine.faults import FaultEvent, FaultPlan
from repro.engine.topology import OperatorSpec, Topology

KGS = 8
NODES = 4

#: hb_interval_s * hb_misses for the hang scenario: long enough that a
#: loaded CI host never trips it spuriously, short enough that the row's
#: MTTR stays readable (it is dominated by this constant by design).
_HANG_DEADLINE_S = 0.5


def _mid(state, keys, values, ts):
    state["n"] = state.get("n", 0) + len(keys)
    return state, (keys + 17, values, ts)


def _sink(state, keys, values, ts):
    state["n"] = state.get("n", 0) + len(keys)
    return state, (keys, values * 2.0, ts)


def make_topo() -> Topology:
    t = Topology()
    t.add_operator(OperatorSpec("src", None, num_keygroups=KGS, is_source=True))
    t.add_operator(OperatorSpec("mid", _mid, num_keygroups=KGS))
    t.add_operator(OperatorSpec("sink", _sink, num_keygroups=KGS, is_sink=True))
    t.connect("src", "mid")
    t.connect("mid", "sink")
    return t


def _batches(ticks: int, batch: int) -> list[tuple]:
    rng = bench_rng("fault_recovery", "stream")
    return [
        (
            rng.integers(0, 5_000, size=batch).astype(np.int64),
            rng.random(batch),
            np.full(batch, float(t)),
        )
        for t in range(ticks)
    ]


def _episode(
    faults: FaultPlan | None,
    batches: list[tuple],
    *,
    periods: int,
    tpp: int,
    supervision: SupervisionPolicy,
) -> dict:
    """One full drive (periods × tpp push+tick, drain each boundary) →
    sink multiset, recovery reports, wall seconds."""
    with tempfile.TemporaryDirectory(prefix="fault_recovery_ck_") as ckdir:
        eng = make_engine(
            make_topo(),
            NODES,
            config=ExecutionConfig.workers(
                2,
                shm=1 << 20,
                checkpoint=CheckpointPolicy(directory=ckdir, every=2),
                supervision=supervision,
            ),
            service_rate=1e9,
            seed=0,
            faults=faults,
        )
        it = iter(batches)
        t0 = time.perf_counter()
        try:
            for _ in range(periods):
                for _ in range(tpp):
                    keys, values, ts = next(it)
                    eng.push_source("src", keys, values, ts)
                    eng.tick()
                eng.end_period()
            while eng.worst_queue_cost() > 0.0:
                eng.tick()
            eng.finalize()
            wall = time.perf_counter() - t0
        finally:
            eng.close()
        return {
            "sinks": collections.Counter(eng.metrics.sink_outputs),
            "recoveries": list(eng.recoveries),
            "wall_s": wall,
        }


def _scenario_row(
    name: str,
    plan: FaultPlan,
    *,
    quick: bool,
    supervision: SupervisionPolicy,
    extra: str = "",
) -> str:
    periods = 4
    tpp = 5 if quick else 8
    batch = 256 if quick else 1024
    repeats = 2 if quick else 3
    batches = _batches(periods * tpp, batch)

    ref = _episode(
        None, batches, periods=periods, tpp=tpp, supervision=supervision
    )
    assert not ref["recoveries"]

    mttrs: list[float] = []
    healed = None
    for _ in range(repeats):
        run = _episode(
            plan, batches, periods=periods, tpp=tpp, supervision=supervision
        )
        if healed is None:
            healed = run
        mttrs.extend(r.mttr_s for r in run["recoveries"] if not r.gave_up)
    lost = sum((ref["sinks"] - healed["sinks"]).values())
    dup = sum((healed["sinks"] - ref["sinks"]).values())
    best = min(mttrs) if mttrs else 0.0
    spread = (max(mttrs) / best) if best > 0 else 1.0
    us_per_tick = healed["wall_s"] / (periods * tpp) * 1e6
    derived = (
        f"mttr_ms={best * 1e3:.2f};tuples_lost={lost};tuples_dup={dup};"
        f"recoveries={len(healed['recoveries'])};spread={spread:.2f}"
    )
    if extra:
        derived += f";{extra}"
    return csv_row(f"fault_recovery/{name}", us_per_tick, derived)


def run(quick: bool = False):
    tpp = 5 if quick else 8
    kill_tick = 2 * tpp + max(tpp // 2, 1)  # mid period 3: checkpoint behind it
    yield _scenario_row(
        "kill_mid_stream",
        FaultPlan.of([FaultEvent("kill", 1, at_tick=kill_tick)]),
        quick=quick,
        supervision=SupervisionPolicy(),
    )
    yield _scenario_row(
        "hang_escalation",
        FaultPlan.of(
            [FaultEvent("hang", 1, at_tick=kill_tick, seconds=30.0)]
        ),
        quick=quick,
        supervision=SupervisionPolicy(hb_interval_s=0.1, hb_misses=5),
        extra=f"deadline_ms={_HANG_DEADLINE_S * 1e3:.0f}",
    )


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)

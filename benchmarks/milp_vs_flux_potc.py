"""Figs 6–7: load distance + #migrations over time — MILP vs Flux vs PoTC on
Real Job 1 (wiki stream, GeoHash→TopK→GlobalTopK), maxMigrations = 13."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row
from repro.core import AdaptationFramework
from repro.core.baselines import PotcSimulator, flux_rebalance
from repro.core.migration import execute_plan, plan_from_allocations
from repro.data import real_job_1, wiki_edit_stream
from repro.data.synthetic import StreamSpec
from repro.engine import Controller, ControllerConfig, Engine

MAX_MIGR = 13


def build(kgs: int, nodes: int, seed: int) -> tuple[Engine, callable]:
    # Node utilization in the paper's EC2 range (~40–70%): the MILP's
    # ceil(mean) target (paper Table 2) is only meaningful when loads are
    # O(10s) of percent, not O(1) — at trivial utilization the ceil bias
    # dominates the load distance.
    topo = real_job_1(keygroups_per_op=kgs)
    eng = Engine(
        topo,
        nodes,
        ser_cost=0.3,
        service_rate=nodes * 90.0,
        seed=seed,
        collect_sinks=False,
    )
    stream = wiki_edit_stream(StreamSpec(rate=350.0, fluctuation=0.4, seed=seed))

    def feeder(engine, tick):
        k, v, ts = next(stream)
        engine.push_source("wiki", k, v, ts)

    return eng, feeder


def run_milp(kgs, nodes, periods, ticks):
    eng, feeder = build(kgs, nodes, seed=bench_seed("milp_vs_flux_potc", "build"))
    ctl = Controller(
        eng,
        AdaptationFramework(mode="milp", max_migrations=MAX_MIGR, time_limit=2.0),
        ControllerConfig(ticks_per_period=ticks),
        feeder=feeder,
    )
    lds, migs = [], []
    for _ in range(periods):
        m = ctl.period()
        lds.append(m.load_distance)
        migs.append(m.num_migrations)
    return lds, migs


def run_flux(kgs, nodes, periods, ticks):
    eng, feeder = build(kgs, nodes, seed=bench_seed("milp_vs_flux_potc", "build"))
    lds, migs = [], []
    for p in range(periods):
        for t in range(ticks):
            feeder(eng, t)
            eng.tick()
        snap = eng.end_period()
        if p >= 1:
            plan = flux_rebalance(snap, max_migrations=MAX_MIGR)
            mp = plan_from_allocations(snap, plan.alloc)
            execute_plan(mp, eng)
            migs.append(mp.num_migrations)
        else:
            migs.append(0)
        lds.append(snap.load_distance(eng.router.table))
    return lds, migs


def run_potc(kgs, nodes, periods, ticks):
    eng, feeder = build(kgs, nodes, seed=bench_seed("milp_vs_flux_potc", "build"))
    sim = None
    lds = []
    for p in range(periods):
        for t in range(ticks):
            feeder(eng, t)
            eng.tick()
        snap = eng.end_period()
        if sim is None:
            sim = PotcSimulator(snap)
        _, ld = sim.step(snap.kg_load)
        lds.append(ld)
    return lds, [0] * periods  # PoTC migrates no state; it splits it


def run(quick: bool = False) -> list[str]:
    kgs, nodes = (50, 10) if quick else (100, 20)
    periods, ticks = (5, 8) if quick else (7, 10)
    rows = []
    for name, fn in (("milp", run_milp), ("flux", run_flux), ("potc", run_potc)):
        t0 = time.perf_counter()
        lds, migs = fn(kgs, nodes, periods, ticks)
        dt = (time.perf_counter() - t0) / periods
        tail = lds[2:]
        rows.append(
            csv_row(
                f"milp_vs_flux_potc/{name}",
                dt * 1e6,
                f"avg_ld={np.mean(tail):.2f};max_ld={np.max(tail):.2f};"
                f"migrations_per_spl={np.mean(migs[2:]):.1f}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

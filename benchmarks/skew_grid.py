"""Skew mitigation grid: ALBIC/MILP vs COLA/Flux/PoTC across skew scenarios.

Every row runs one scenario from :mod:`repro.workloads` (zipf, flash_crowd,
diurnal, churn — the shapes on which the paper's comparative claims actually
differentiate) against one mitigation strategy on a mergeable aggregation
job, and reports:

``imbalance``      steady-state relative node imbalance, (max − mean) / mean
                   over alive nodes — gated (a regression here means a
                   balancer got worse at its one job)
``migcost``        mean migration cost per adaptation period — gated (cheap
                   adaptation is half the paper's point)
``imbalance_max``  worst single period (the surge transient), reported only
``latency_p99``    p99 of the engine's tuple latency proxy, reported only
``hot_residency``  mean hottest-key-group share of period arrivals
                   (EngineMetrics.max_kg_share), reported only

The ``+split`` variants run the framework-wired hot-key splitting path
(``ExecutionConfig.split`` + ``HotKeySplitter``): the flash-crowd scenario is
the one migration alone cannot fix — its hot key group exceeds a node's fair
share, so every no-split balancer leaves one node overloaded while the split
variants fan the hot key group across replicas.

All randomness threads through :func:`benchmarks.common.bench_seed`.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row
from repro.core import AdaptationFramework, AlbicParams
from repro.core.baselines import PotcSimulator, cola_allocate, flux_rebalance
from repro.core.migration import execute_plan, plan_from_allocations
from repro.core.splitting import HotKeySplitter
from repro.engine import Engine, ExecutionConfig
from repro.engine.topology import OperatorSpec, Topology
from repro.workloads import GRID_SCENARIOS, make_scenario, scenario_batches

MAX_MIGR = 13
SPLIT_DEGREE = 4
BALANCERS = ("albic", "milp", "cola", "flux", "potc")
SPLIT_BALANCERS = ("albic", "milp")  # the framework-wired methods


def _merge_counts(a: dict, b: dict) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + v
    return out


def _agg(state, keys, values, ts):
    # Delta-emitting count per entity: commutative state, so the operator is
    # split-mergeable (each replica counts its share; merge adds them).
    for k in keys.tolist():
        state[k] = state.get(k, 0) + 1
    return state, (keys, np.ones(len(keys), dtype=np.int64), ts)


def _total_sink(state, keys, values, ts):
    for k, v in zip(keys.tolist(), values.tolist()):
        state[k] = state.get(k, 0) + v
    return state, None


def skew_job(kgs_per_op: int) -> Topology:
    """events → agg (count deltas) → total: both stateful stages declare
    ``merge_state``, so the splitter may fan either layer's hot key group.
    The source carries a token cost — its key groups cannot split (no state
    to merge), so keeping them light keeps the *balanceable* load dominant."""
    t = Topology()
    t.add_operator(
        OperatorSpec(
            "events", None, num_keygroups=kgs_per_op, is_source=True,
            cost_per_tuple=0.05,
        )
    )
    t.add_operator(
        OperatorSpec(
            "agg", _agg, num_keygroups=kgs_per_op, merge_state=_merge_counts
        )
    )
    t.add_operator(
        OperatorSpec(
            "total", _total_sink, num_keygroups=kgs_per_op, is_sink=True,
            cost_per_tuple=0.5, merge_state=_merge_counts,
        )
    )
    t.connect("events", "agg")
    t.connect("agg", "total")
    return t


def _imbalance(loads: np.ndarray) -> float:
    mean = float(loads.mean())
    if mean <= 0.0:
        return 0.0
    return (float(loads.max()) - mean) / mean


def episode(
    scenario: str,
    balancer: str,
    *,
    split: bool,
    nodes: int,
    kgs: int,
    periods: int,
    ticks: int,
    rate: float,
    key_space: int,
) -> dict[str, float]:
    """One (scenario, balancer, ±split) run → the row's derived metrics."""
    spec = make_scenario(
        scenario,
        rate=rate,
        key_space=key_space,
        seed=bench_seed("skew_grid", scenario),
    )
    batches = iter(scenario_batches(spec, periods * ticks))
    config = (
        ExecutionConfig.split(SPLIT_DEGREE) if split else ExecutionConfig.typed()
    )
    eng = Engine(
        skew_job(kgs),
        nodes,
        service_rate=nodes * 110.0,
        seed=bench_seed("skew_grid", "alloc"),
        collect_sinks=False,
        config=config,
    )
    fw = None
    if balancer in ("albic", "milp"):
        fw = AdaptationFramework(
            mode=balancer,
            max_migrations=MAX_MIGR,
            time_limit=2.0,
            albic_params=AlbicParams(time_limit=1.0),
            splitter=HotKeySplitter() if split else None,
        )
    sim = None
    imb, migcost, residency = [], [], []
    for p in range(periods):
        for _ in range(ticks):
            keys, values, ts = next(batches)
            if len(keys):
                eng.push_source("events", keys, values, ts)
            eng.tick()
        snap = eng.end_period()
        residency.append(eng.metrics.max_kg_share)
        cost = 0.0
        if balancer == "potc":
            # Simulated baseline (no engine-side migration): greedy
            # two-choice routing over the measured loads, merge overhead
            # included — the milp_vs_flux_potc idiom.
            if sim is None:
                sim = PotcSimulator(snap)
            loads, _ = sim.step(snap.kg_load)
            imb.append(_imbalance(loads[snap.alive]))
            migcost.append(0.0)
            continue
        if p >= 1:
            if fw is not None:
                result = fw.adapt(
                    snap,
                    split_families=eng.split_families() if split else None,
                    split_eligible=eng.split_eligible() if split else None,
                )
                execute_plan(result.migration_plan, eng)
                cost = result.migration_plan.total_cost
                if result.split is not None:
                    for kg in result.split.unsplit:
                        eng.unsplit_keygroup(kg)
                    for kg in result.split.split:
                        if eng.split_slots_free < SPLIT_DEGREE - 1:
                            break
                        eng.split_keygroup(kg)
            elif balancer == "flux":
                plan = flux_rebalance(snap, max_migrations=MAX_MIGR)
                mp = plan_from_allocations(snap, plan.alloc)
                execute_plan(mp, eng)
                cost = mp.total_cost
            elif balancer == "cola":
                plan = cola_allocate(
                    snap, seed=bench_seed("skew_grid", "cola", p)
                )
                mp = plan_from_allocations(snap, plan.alloc)
                execute_plan(mp, eng)
                cost = mp.total_cost
        # Next-period balance of this period's measured load under the
        # post-adaptation placement (standard leading evaluation).
        loads = snap.node_loads(eng.router.table)
        imb.append(_imbalance(loads[eng.alive]))
        migcost.append(cost)
    lat = eng.latency.summary()
    steady = slice(max(periods - 3, 1), None)
    return {
        "imbalance": float(np.mean(imb[steady])),
        "imbalance_max": float(np.max(imb[1:])),
        "migcost": float(np.mean(migcost[1:])),
        "latency_p99": float(lat["p99"]),
        "hot_residency": float(np.mean(residency[1:])),
    }


def run(quick: bool = False) -> list[str]:
    nodes, kgs = (8, 16) if quick else (12, 32)
    periods, ticks = (6, 8) if quick else (10, 12)
    rate, key_space = (192.0, 512) if quick else (384.0, 2048)
    rows = []
    for scenario in GRID_SCENARIOS:
        for balancer in BALANCERS:
            variants = [False]
            if balancer in SPLIT_BALANCERS:
                variants.append(True)
            for split in variants:
                t0 = time.perf_counter()
                m = episode(
                    scenario,
                    balancer,
                    split=split,
                    nodes=nodes,
                    kgs=kgs,
                    periods=periods,
                    ticks=ticks,
                    rate=rate,
                    key_space=key_space,
                )
                dt = (time.perf_counter() - t0) / periods
                name = balancer + ("+split" if split else "")
                rows.append(
                    csv_row(
                        f"skew_grid/{scenario}/{name}",
                        dt * 1e6,
                        f"imbalance={m['imbalance']:.3f};"
                        f"migcost={m['migcost']:.1f};"
                        f"imbalance_max={m['imbalance_max']:.3f};"
                        f"latency_p99={m['latency_p99']:.1f};"
                        f"hot_residency={m['hot_residency']:.3f}",
                    )
                )
    return rows


def main() -> None:
    for row in run(quick=True):
        print(row)


if __name__ == "__main__":
    main()

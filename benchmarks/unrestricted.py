"""Figs 8–9: load-balance quality vs overhead as the migration budget varies
(10 / 13 / 20 / unrestricted), on the Real-Job-1 engine workload."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row
from benchmarks.milp_vs_flux_potc import build
from repro.core import AdaptationFramework
from repro.engine import Controller, ControllerConfig


def run(quick: bool = False) -> list[str]:
    budgets = [10, None] if quick else [10, 13, 20, None]
    periods, ticks = (4, 8) if quick else (7, 12)
    rows = []
    for budget in budgets:
        eng, feeder = build(
            50 if quick else 100,
            10 if quick else 20,
            seed=bench_seed("unrestricted", "build"),
        )
        ctl = Controller(
            eng,
            AdaptationFramework(
                mode="milp",
                max_migrations=budget,
                time_limit=2.0,
            ),
            ControllerConfig(ticks_per_period=ticks),
            feeder=feeder,
        )
        t0 = time.perf_counter()
        for _ in range(periods):
            m = ctl.period()
        dt = (time.perf_counter() - t0) / periods
        h = ctl.history[1:]
        rows.append(
            csv_row(
                f"unrestricted/m{'inf' if budget is None else budget}",
                dt * 1e6,
                f"avg_ld={np.mean([x.load_distance for x in h]):.2f};"
                f"max_ld={np.max([x.load_distance for x in h]):.2f};"
                f"total_migrations={sum(x.num_migrations for x in h)};"
                f"pause_s={sum(x.migration_pause_s for x in h):.3f}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

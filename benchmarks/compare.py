"""Perf-regression gate: compare a benchmark JSON against the baseline.

CI runs ``python -m benchmarks.run --quick --json BENCH_<sha>.json`` and then
``python -m benchmarks.compare benchmarks/baseline.json BENCH_<sha>.json``;
the job fails when any gated row regressed by more than ``--threshold``
(default 20%).  Gated rows are the ones whose module prefix is in
``--modules`` (default: the perf-critical suites — engine_throughput,
solver_perf, and the per-job real_jobs rows: the fn_seg/columnar throughput
rows, the record-pipeline columnar-vs-object row, and the schema-typed
migration round-trip row) and whose baseline time clears ``--min-us`` —
sub-50µs rows are noise, not signal.  Per-unit times embedded in a row's
derived column (``*_us_per_tick`` entries, e.g. the multiworker row's
exchange costs) gate the same way, as ``<row>:<key>`` sub-rows.

Rows measured best-of-N embed a ``spread=`` entry (best/worst across the
repeats) in their derived column; the gate report prints it alongside each
ratio so a noisy row is distinguishable from a real regression at a glance.

Candidate-only rows (present in the new JSON, absent from the baseline) are
reported explicitly as "new, ungated" rather than silently skipped, so a
fresh row and a typo'd rename are distinguishable in the gate output.

To update the committed baseline after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.run --quick \
        --only solver_perf,engine_throughput,real_jobs,skew_grid,fault_recovery \
        --json benchmarks/baseline.json

The baseline is machine-dependent: refresh it from the same class of runner
the gate executes on (for GitHub Actions, a ubuntu-latest runner).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

DEFAULT_MODULES = (
    "engine_throughput",
    "solver_perf",
    "real_jobs",
    "skew_grid",
    "fault_recovery",
)
DEFAULT_THRESHOLD = 1.20  # fail if new time > 1.2 × baseline time
DEFAULT_MIN_US = 50.0

# Figure-timeline rows (ALBIC/COLA adaptation periods) time solver runs and
# migration execution — inherently noisy and already bounded by their own
# time limits, so they are reported but never gated.
UNGATED_MARKER = "_fig"


@dataclasses.dataclass
class Comparison:
    name: str
    base_us: float
    new_us: float

    @property
    def ratio(self) -> float:
        return self.new_us / self.base_us if self.base_us > 0 else float("inf")


# Derived-column entries whose key ends with one of these suffixes gate
# exactly like a row's us_per_call, under the name ``<row>:<key>``.
# ``_us_per_tick`` entries are per-unit times (the multiworker row's
# exchange costs: the shm transport's win is invisible in wall-clock
# us_per_call on a small host, so the gate watches the exchange time
# itself).  ``imbalance`` / ``migcost`` are the skew grid's quality
# columns — not times at all, but a >20% regression in either means a
# balancer got worse at its one job, which is exactly what the gate is
# for.  Sub-rows bypass the ``--min-us`` noise floor (it is a *time*
# floor; quality metrics gate on any positive baseline).
# ``mttr_ms`` is the fault_recovery rows' mean-time-to-repair (best-of-N,
# death detection → cluster serving): a regression there means the
# self-healing path itself got slower.
GATED_DERIVED_SUFFIXES = ("_us_per_tick", "imbalance", "migcost", "mttr_ms")


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, float] = {}
    for r in doc.get("rows", []):
        out[r["name"]] = float(r["us_per_call"])
        for part in str(r.get("derived", "")).split(";"):
            key, _, val = part.partition("=")
            if key.endswith(GATED_DERIVED_SUFFIXES):
                try:
                    out[f"{r['name']}:{key}"] = float(val)
                except ValueError:
                    pass
    return out


def load_spreads(path: str) -> dict[str, float]:
    """Per-row best-of-N spread (best/worst across a run's repeats), parsed
    from the ``spread=`` entry benchmark modules embed in the derived
    column.  Rows without one simply don't appear."""
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, float] = {}
    for r in doc.get("rows", []):
        for part in str(r.get("derived", "")).split(";"):
            if part.startswith("spread="):
                try:
                    out[r["name"]] = float(part[len("spread="):])
                except ValueError:
                    pass
    return out


def compare(
    baseline: dict[str, float],
    new: dict[str, float],
    *,
    modules: tuple[str, ...] = DEFAULT_MODULES,
    threshold: float = DEFAULT_THRESHOLD,
    min_us: float = DEFAULT_MIN_US,
) -> tuple[list[Comparison], list[Comparison]]:
    """Return (all gated comparisons, regressions beyond the threshold)."""
    gated: list[Comparison] = []
    regressions: list[Comparison] = []
    for name, base_us in sorted(baseline.items()):
        module = name.split("/", 1)[0]
        if module not in modules or UNGATED_MARKER in name:
            continue
        if name not in new:
            continue  # renamed/removed rows don't fail the gate
        c = Comparison(name, base_us, new[name])
        gated.append(c)
        # The min-us noise floor applies to plain timing rows only:
        # ``<row>:<key>`` sub-rows carry per-unit times or quality metrics
        # whose magnitudes are far below it by construction, so they gate
        # whenever the baseline value is meaningful (> 0 — a zero baseline
        # has no ratio).
        floor_ok = base_us > 0.0 if ":" in name else base_us >= min_us
        if floor_ok and c.ratio > threshold:
            regressions.append(c)
    return gated, regressions


def candidate_only(
    baseline: dict[str, float],
    new: dict[str, float],
    *,
    modules: tuple[str, ...] = DEFAULT_MODULES,
) -> list[str]:
    """Gated-module rows present only in the candidate run.

    These are new measurements with nothing to compare against — they pass
    the gate by definition, but silently skipping them made a typo'd row
    rename look identical to a fresh row, so the report calls them out as
    "new, ungated" until the baseline is refreshed."""
    return sorted(
        name
        for name in new
        if name not in baseline
        and name.split("/", 1)[0] in modules
        and UNGATED_MARKER not in name
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("new", help="freshly measured JSON")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    ap.add_argument("--min-us", type=float, default=DEFAULT_MIN_US)
    ap.add_argument(
        "--modules",
        default=",".join(DEFAULT_MODULES),
        help="comma-separated module prefixes to gate",
    )
    args = ap.parse_args(argv)

    baseline = load_rows(args.baseline)
    new = load_rows(args.new)
    modules = tuple(m for m in args.modules.split(",") if m)
    gated, regressions = compare(
        baseline, new, modules=modules, threshold=args.threshold, min_us=args.min_us
    )

    if not gated:
        print("perf gate: no comparable rows — check module names", file=sys.stderr)
        return 2
    spreads = load_spreads(args.new)
    width = max(len(c.name) for c in gated)
    print(f"{'row'.ljust(width)}  baseline_us   new_us     ratio  spread")
    for c in gated:
        flag = "  << REGRESSION" if c in regressions else ""
        spread = spreads.get(c.name)
        sp = f"{spread:6.2f}" if spread is not None else "     -"
        print(
            f"{c.name.ljust(width)}  {c.base_us:11.1f}  {c.new_us:9.1f}  {c.ratio:7.2f}  {sp}{flag}"
        )
    fresh = candidate_only(baseline, new, modules=modules)
    if fresh:
        print(f"\n{len(fresh)} candidate-only row(s) — new, ungated:")
        for name in fresh:
            print(f"  {name}  (no baseline entry; refresh benchmarks/baseline.json)")
    if regressions:
        print(
            f"\nperf gate FAILED: {len(regressions)} row(s) regressed "
            f"more than {(args.threshold - 1) * 100:.0f}% vs baseline",
            file=sys.stderr,
        )
        return 1
    print(f"\nperf gate OK ({len(gated)} rows within {(args.threshold-1)*100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

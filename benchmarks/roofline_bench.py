"""Roofline table: reads the dry-run results (experiments/dryrun_results.json)
and emits one row per (arch × shape × mesh) with the three terms, the
dominant bottleneck, and the useful-FLOPs ratio."""

from __future__ import annotations

import json
import os

from benchmarks.common import csv_row

RESULTS = os.environ.get("DRYRUN_RESULTS", "experiments/dryrun_results.json")


def run(quick: bool = False) -> list[str]:  # noqa: ARG001 - table read, no quick mode
    if not os.path.exists(RESULTS):
        return [
            csv_row("roofline/missing", 0.0, f"no {RESULTS}; run repro.launch.dryrun"),
        ]
    with open(RESULTS) as f:
        rows_in = json.load(f)
    rows = []
    for r in rows_in:
        if r.get("status") == "skip":
            rows.append(
                csv_row(
                    f"roofline/{r['arch']}/{r['shape']}/-",
                    0.0,
                    f"SKIP:{r.get('reason','')[:60]}",
                )
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                csv_row(
                    f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh','?')}",
                    0.0,
                    f"FAIL:{r.get('error','')[:60]}",
                )
            )
            continue
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            csv_row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                bound_s * 1e6,  # the roofline-bound step time
                f"dominant={r['dominant']};compute={r['compute_s']:.3f}s;"
                f"memory={r['memory_s']:.3f}s;collective={r['collective_s']:.3f}s;"
                f"useful={r['useful_ratio']:.2f}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

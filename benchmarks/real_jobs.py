"""Real Jobs 1–4 on the live engine.

Three row families:

* ``real_jobs/jobN_seg_throughput`` — raw data-plane tuples/sec per job with
  the segment-vectorized operators (``fn_seg``, the production path), the
  per-run ``fn`` fallback, and the frozen pre-PR baseline; the derived
  column reports the speedups.  The gated ``us_per_call`` is the per-tick
  wall time of the fn_seg path.
* ``real_jobs/jobN_jit_throughput`` (jobs 2–3) — the compiled tier
  (``use_fn_jit=True``) against the numpy ``fn_seg`` path on identical
  engines and data: steady-state only (a full warm-up pass absorbs every
  padding-bucket compile; first-call trace+compile seconds are reported
  separately in the derived column).  On CPU the jit tier currently runs
  at a fraction of the hand-tuned numpy path (XLA CPU's comparison sort
  and per-call host↔device boundary dominate — see ROADMAP); the row
  exists to pin that ratio and catch regressions as the tier evolves
  toward the accelerator backends it targets.
* ``real_jobs/jobN_figNN/{albic,cola}`` — Figs 12–14 timelines of
  collocation factor, load distance, load index and migrations.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, csv_row
from repro.core import AdaptationFramework, AlbicParams
from repro.core.migration import execute_plan, plan_from_allocations
from repro.core.baselines import cola_allocate
from repro.data import airline_stream, real_job_2, real_job_3, real_job_4
from repro.data.jobs import make_real_job_1
from repro.data.synthetic import StreamSpec, weather_stream, wiki_edit_stream
from repro.engine import Controller, ControllerConfig, Engine, ExecutionConfig

JOBS = {
    "job2_fig12": (real_job_2, ("airline",)),
    "job3_fig13": (real_job_3, ("airline",)),
    "job4_fig14": (real_job_4, ("airline", "weather")),
}

# ---------------------------------------------------------------------------
# Pre-PR baseline reproduction (frozen).
#
# The fn_seg port also rewrote the airline jobs' per-run bodies (dict
# payloads → record tuples, identity/int-code partitioning), so the current
# ``use_fn_seg=False`` path is already faster than what shipped before the
# port.  To report an honest per-job speedup, the pre-port operators are
# frozen here verbatim (dict values, key_by_value partitioning) and measured
# on the same data.  They run on today's engine, whose routing also got
# faster — so the reported speedup *understates* the true delta versus the
# historical tree.  Job 1's bodies were not rewritten; its baseline is the
# current topology with fn_seg disabled.
# ---------------------------------------------------------------------------


def _legacy_extract(state, keys, values, ts):
    out = []
    for k, v, t in zip(keys, values, ts):
        delay = v["dep_delay"] + v["arr_delay"]
        out.append(
            (
                v["airplane"],
                {
                    "airplane": v["airplane"],
                    "delay": delay,
                    "year": v["year"],
                    "origin": v["origin"],
                    "dest": v["dest"],
                },
                float(t),
            )
        )
    return state, out


def _legacy_sum_delay(state, keys, values, ts):
    sums = state.setdefault("sums", {})
    out = []
    for k, v, t in zip(keys, values, ts):
        key = (v["airplane"], v["year"])
        sums[key] = sums.get(key, 0.0) + v["delay"]
        out.append(
            (v["airplane"], {"airplane": v["airplane"], "sum": sums[key]}, float(t))
        )
    return state, out


def _legacy_route_delay(state, keys, values, ts):
    from repro.data import synthetic

    sums = state.setdefault("route_sums", {})
    out = []
    for k, v, t in zip(keys, values, ts):
        route = (v["origin"], v["dest"])
        sums[route] = sums.get(route, 0.0) + v["delay"]
        out.append(
            (
                v["origin"] * synthetic.num_airports() + v["dest"],
                {
                    "route": route,
                    "origin": v["origin"],
                    "sum": sums[route],
                    "delay": v["delay"],
                },
                float(t),
            )
        )
    return state, out


def _legacy_job_2(keygroups_per_op: int):
    from repro.engine.topology import OperatorSpec, Topology

    t = Topology()
    t.add_operator(
        OperatorSpec("airline", None, num_keygroups=keygroups_per_op, is_source=True)
    )
    t.add_operator(
        OperatorSpec(
            "extract",
            _legacy_extract,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["airplane"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "sumdelay",
            _legacy_sum_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["airplane"],
            is_sink=True,
        )
    )
    t.connect("airline", "extract")
    t.connect("extract", "sumdelay")
    return t


def _legacy_job_3(keygroups_per_op: int):
    from repro.engine.topology import OperatorSpec

    t = _legacy_job_2(keygroups_per_op)
    t.add_operator(
        OperatorSpec(
            "routedelay",
            _legacy_route_delay,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: (v["origin"], v["dest"]),
            is_sink=True,
        )
    )
    t.connect("extract", "routedelay")
    return t


def _legacy_job_4(keygroups_per_op: int):
    from repro.data import synthetic
    from repro.engine.topology import OperatorSpec

    def rainscore(state, keys, values, ts):
        out = []
        for k, v, t in zip(keys, values, ts):
            score = 100.0 * v["precip"] / synthetic.max_precip()
            out.append(
                (v["airport"], {"airport": v["airport"], "rainscore": score}, float(t))
            )
        return state, out

    def join_route_rain(state, keys, values, ts):
        rain = state.setdefault("rain", {})
        out = []
        for k, v, t in zip(keys, values, ts):
            if "rainscore" in v:
                rain[v["airport"]] = v["rainscore"]
            else:
                score = rain.get(v["origin"], 0.0)
                out.append(
                    (v["origin"], {"delay": v["delay"], "rainscore": score}, float(t))
                )
        return state, out

    def courier_efficiency(state, keys, values, ts):
        buckets = state.setdefault("buckets", {})
        out = []
        for k, v, t in zip(keys, values, ts):
            b = min(int(v["rainscore"] // 10), 9)
            buckets[b] = buckets.get(b, 0.0) + v["delay"]
            out.append((b, {"bucket": b, "sum_delay": buckets[b]}, float(t)))
        return state, out

    def store_op(state, keys, values, ts):
        rows = state.setdefault("rows", [])
        for k, v, t in zip(keys, values, ts):
            rows.append((int(k), v["sum_delay"], float(t)))
        if len(rows) > 1_000:
            del rows[:-100]
        return state, []

    t = _legacy_job_3(keygroups_per_op)
    t.operators[t._resolve("routedelay")].is_sink = False
    t.add_operator(
        OperatorSpec("weather", None, num_keygroups=keygroups_per_op, is_source=True)
    )
    t.add_operator(
        OperatorSpec(
            "rainscore",
            rainscore,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["station"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "join",
            join_route_rain,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: v["airport"] if "airport" in v else v["origin"],
        )
    )
    t.add_operator(
        OperatorSpec(
            "efficiency",
            courier_efficiency,
            num_keygroups=keygroups_per_op,
            key_by_value=lambda v: min(int(v["rainscore"] // 10), 9),
        )
    )
    t.add_operator(
        OperatorSpec("store", store_op, num_keygroups=keygroups_per_op, is_sink=True)
    )
    t.connect("weather", "rainscore")
    t.connect("rainscore", "join")
    t.connect("routedelay", "join")
    t.connect("join", "efficiency")
    t.connect("efficiency", "store")
    return t


_AIRLINE_DICT_FIELDS = ("airplane", "origin", "dest", "dep_delay", "arr_delay", "year")
_WEATHER_DICT_FIELDS = ("station", "precip", "mean_temp", "visibility", "airport")


def _legacy_batches(batches):
    """The same pre-generated data with airline/weather records as dicts (the
    pre-PR payload representation; the structured stream arrays ``tolist`` to
    the identical record tuples).  Conversion stays outside the timed
    region."""
    out = []
    for tick in batches:
        row = []
        for op, keys, values, ts in tick:
            if op == "airline":
                values = [
                    dict(zip(_AIRLINE_DICT_FIELDS, v)) for v in values.tolist()
                ]
            elif op == "weather":
                values = [
                    dict(zip(_WEATHER_DICT_FIELDS, v)) for v in values.tolist()
                ]
            row.append((op, keys, values, ts))
        out.append(row)
    return out


LEGACY_JOBS = {
    "job2": _legacy_job_2,
    "job3": _legacy_job_3,
    "job4": _legacy_job_4,
}

# ---------------------------------------------------------------------------
# Per-job data-plane throughput: fn_seg vs per-run fn vs the pre-PR baseline.
# ---------------------------------------------------------------------------

THROUGHPUT_JOBS = {
    # Short TopK windows so job 1's windowed reductions actually fire.
    "job1": (
        lambda kgs: make_real_job_1(keygroups_per_op=kgs, window_ticks=4.0),
        ("wiki",),
    ),
    "job2": (lambda kgs: real_job_2(keygroups_per_op=kgs), ("airline",)),
    "job3": (lambda kgs: real_job_3(keygroups_per_op=kgs), ("airline",)),
    "job4": (lambda kgs: real_job_4(keygroups_per_op=kgs), ("airline", "weather")),
}


def _pregenerate(sources: tuple[str, ...], *, rate: float, ticks: int, seed: int):
    """Materialize every source batch up front so stream generation (python
    dict building) stays out of the timed region."""
    streams = {}
    if "wiki" in sources:
        streams["wiki"] = wiki_edit_stream(StreamSpec(rate=rate, seed=seed))
    if "airline" in sources:
        streams["airline"] = airline_stream(StreamSpec(rate=rate, seed=seed))
    if "weather" in sources:
        streams["weather"] = weather_stream(StreamSpec(rate=rate / 4, seed=seed))
    return [[(op, *next(it)) for op, it in streams.items()] for _ in range(ticks + 1)]


def _object_batches(batches):
    """The same data with values as boxed record-tuple lists — what the
    ``use_schema=False`` oracle engines ingested before the streams went
    columnar.  Decayed here, outside the timed region, so the object-path
    rows keep measuring execution, not ingestion decay."""
    return [
        [(op, keys, values.tolist(), ts) for op, keys, values, ts in tick]
        for tick in batches
    ]


def _run_once(
    topo_factory, kgs, batches, *, use_fn_seg: bool = True, use_schema: bool = True
) -> tuple[float, float]:
    """One engine run over the pre-generated batches → (tuples/s, s/tick)."""
    eng = Engine(
        topo_factory(kgs),
        num_nodes=8,
        service_rate=1e12,
        seed=0,
        collect_sinks=False,
        config=ExecutionConfig(use_fn_seg=use_fn_seg, use_schema=use_schema),
    )
    # Warm-up tick: store/window allocation outside the timed region.
    for op, keys, values, ts in batches[0]:
        eng.push_source(op, keys, values, ts)
    eng.tick()
    start = eng.metrics.processed_tuples
    t0 = time.perf_counter()
    for tick_batches in batches[1:]:
        for op, keys, values, ts in tick_batches:
            eng.push_source(op, keys, values, ts)
        eng.tick()
    dt = time.perf_counter() - t0
    return (eng.metrics.processed_tuples - start) / dt, dt / (len(batches) - 1)


def measure_job_throughput(
    job_key: str, *, kgs: int, rate: float, ticks: int, repeats: int = 3
) -> dict[str, float]:
    """Best-of-``repeats`` tuples/sec for one job, on four execution paths:
    schema-typed fn_seg (production: columnar structured-array edges),
    object-path fn_seg (``use_schema=False`` — the pre-schema fn_seg
    numbers), per-run fn (the oracle fallback on today's job bodies), and
    the frozen pre-PR baseline.  The same pre-generated batches feed every
    run, so the comparison (and the gated per-tick time) measures the
    execution paths, not the sources.
    """
    topo_factory, sources = THROUGHPUT_JOBS[job_key]
    batches = _pregenerate(
        sources, rate=rate, ticks=ticks, seed=bench_seed("real_jobs", "stream")
    )
    obj_batches = _object_batches(batches)
    legacy_factory = LEGACY_JOBS.get(job_key)
    variants = {
        "seg": (topo_factory, batches, True, True),
        "obj": (topo_factory, obj_batches, True, False),
        "fn": (topo_factory, obj_batches, False, False),
    }
    if legacy_factory is not None:
        variants["legacy"] = (legacy_factory, _legacy_batches(batches), False, False)
    best = {label: 0.0 for label in variants}
    tick_s = {label: float("inf") for label in variants}
    for _ in range(max(repeats, 1)):
        for label, (factory, data, use_seg, use_schema) in variants.items():
            tps, spt = _run_once(
                factory, kgs, data, use_fn_seg=use_seg, use_schema=use_schema
            )
            best[label] = max(best[label], tps)
            tick_s[label] = min(tick_s[label], spt)
    # Job 1's per-run bodies are unchanged from before the port, so its
    # pre-PR baseline IS the fn path.
    legacy_tps = best.get("legacy", best["fn"])
    return {
        "seg_tps": best["seg"],
        "obj_tps": best["obj"],
        "fn_tps": best["fn"],
        "legacy_tps": legacy_tps,
        "speedup": best["seg"] / max(legacy_tps, 1e-9),
        "obj_speedup": best["seg"] / max(best["obj"], 1e-9),
        "fn_speedup": best["seg"] / max(best["fn"], 1e-9),
        "seg_us_per_tick": tick_s["seg"] * 1e6,
    }


JIT_JOBS = ("job2", "job3")


def measure_job_jit(
    job_key: str, *, kgs: int, rate: float, ticks: int, repeats: int = 3
) -> dict[str, float]:
    """Compiled tier (``use_fn_jit=True``) vs the numpy fn_seg path on one
    flight-delay job, same engine configuration and pre-generated batches.

    Each engine takes one full warm-up pass (every padding bucket compiles
    there; tables reach steady capacity), then the timed pass measures
    steady state — first-call trace+compile seconds are reported
    separately, never inside the throughput number.
    """
    topo_factory, sources = THROUGHPUT_JOBS[job_key]
    batches = _pregenerate(
        sources, rate=rate, ticks=ticks, seed=bench_seed("real_jobs", "stream")
    )
    out: dict[str, float] = {}
    for label, use_jit in (("jit", True), ("seg", False)):
        best = 0.0
        tick_s = float("inf")
        for _ in range(max(repeats, 1)):
            eng = Engine(
                topo_factory(kgs),
                num_nodes=8,
                service_rate=1e12,
                seed=0,
                collect_sinks=False,
                config=ExecutionConfig.jit() if use_jit else ExecutionConfig.typed(),
            )
            for tick_batches in batches:  # warm-up pass: compiles, tables
                for op, keys, values, ts in tick_batches:
                    eng.push_source(op, keys, values, ts)
                eng.tick()
            start = eng.metrics.processed_tuples
            t0 = time.perf_counter()
            for tick_batches in batches:
                for op, keys, values, ts in tick_batches:
                    eng.push_source(op, keys, values, ts)
                eng.tick()
            dt = time.perf_counter() - t0
            best = max(best, (eng.metrics.processed_tuples - start) / dt)
            tick_s = min(tick_s, dt / len(batches))
            if use_jit and eng._jit is not None:
                # First repeat carries the real compiles; later repeats hit
                # the process-wide cache.
                out["compile_s"] = max(
                    out.get("compile_s", 0.0), eng._jit.compile_seconds
                )
        out[label] = best
        out[f"{label}_us_per_tick"] = tick_s * 1e6
    out["jit_vs_seg"] = out["jit"] / max(out["seg"], 1e-9)
    return out


def measure_migration_roundtrip(
    *, kgs: int = 40, n_tuples: int = 20_000, warm_ticks: int = 4, repeats: int = 3
) -> dict[str, float]:
    """serialize→install cost of migrating every extract key group of job 2
    with a large queued backlog, schema-typed vs object path.

    The blob of each key group carries its σ_k state plus the queued
    segments ``redirect`` masked out of the source queue — raw buffer slices
    on the typed path, pickled boxed tuples on the object path.  Returns
    best-of-``repeats`` seconds and the average blob bytes per path.
    """
    air = airline_stream(
        StreamSpec(rate=float(n_tuples), seed=bench_seed("real_jobs", "stream"))
    )
    warm = [next(air) for _ in range(warm_ticks)]
    backlog = next(air)
    out: dict[str, float] = {}
    for label, use_schema in (("typed", True), ("obj", False)):
        best = float("inf")
        for _ in range(max(repeats, 1)):
            eng = Engine(
                real_job_2(keygroups_per_op=kgs),
                4,
                service_rate=1e12,
                seed=0,
                collect_sinks=False,
                config=ExecutionConfig(use_schema=use_schema),
            )
            for k, v, ts in warm:  # accumulate real sumdelay state
                eng.push_source("airline", k, v, ts)
                eng.tick()
            # Land the backlog in extract's queues: the push routes to the
            # airline source's own key groups, and this tick's source drain
            # flushes it to extract at end of tick — after extract already
            # drained — so it sits queued there.  No further ticks run, so
            # the redirect loop below migrates exactly these n_tuples
            # records (plus each key group's σ_k) per blob.
            k, v, ts = backlog
            eng.push_source("airline", k, v, ts)
            eng.tick()
            base = eng.topology.kg_base(1)  # extract owns the queued work
            bytes_total = 0
            t0 = time.perf_counter()
            for kg in range(base, base + kgs):
                dst = (eng.router.node_of(kg) + 1) % eng.num_nodes
                eng.redirect(kg, dst)
                blob = eng.serialize(kg)
                bytes_total += len(blob)
                eng.install(kg, dst, blob)
            dt = time.perf_counter() - t0
            best = min(best, dt)
            out[f"{label}_bytes"] = bytes_total / kgs
        out[label] = best
    out["speedup"] = out["obj"] / max(out["typed"], 1e-12)
    return out


def build(job_key: str, kgs: int, nodes: int, seed: int):
    job_fn, sources = JOBS[job_key]
    topo = job_fn(keygroups_per_op=kgs)
    g = topo.num_keygroups
    # Anti-collocated initial allocation (paper: minimal initial collocation).
    alloc = np.zeros(g, dtype=np.int64)
    for op in range(topo.num_operators):
        base = topo.kg_base(op)
        n_op = topo.operators[op].num_keygroups
        alloc[base : base + n_op] = (np.arange(n_op) + op * (nodes // 2 + 1)) % nodes
    eng = Engine(
        topo,
        nodes,
        initial_alloc=alloc,
        ser_cost=0.6,
        service_rate=3000.0,
        seed=seed,
        collect_sinks=False,  # long runs: don't accumulate sink tuples
    )
    air = airline_stream(StreamSpec(rate=220.0, seed=seed))
    wx = weather_stream(StreamSpec(rate=80.0, seed=seed))

    def feeder(engine, tick):
        k, v, ts = next(air)
        engine.push_source("airline", k, v, ts)
        if "weather" in sources:
            k, v, ts = next(wx)
            engine.push_source("weather", k, v, ts)

    return eng, feeder


def run_albic(job_key, kgs, nodes, periods, ticks):
    eng, feeder = build(job_key, kgs, nodes, seed=bench_seed("real_jobs", "build"))
    ctl = Controller(
        eng,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=15.0, time_limit=1.5),
        ),
        ControllerConfig(ticks_per_period=ticks),
        feeder=feeder,
    )
    for _ in range(periods):
        m = ctl.period()
    h = ctl.history
    return {
        "collocation": m.collocation_factor,
        "avg_ld": float(np.mean([x.load_distance for x in h[1:]])),
        "load_index": m.load_index,
        "migrations_per_spl": float(np.mean([x.num_migrations for x in h[1:]])),
    }


def run_cola(job_key, kgs, nodes, periods, ticks):
    eng, feeder = build(job_key, kgs, nodes, seed=bench_seed("real_jobs", "build"))
    load_index_base = None
    metrics = {}
    for p in range(periods):
        for t in range(ticks):
            feeder(eng, t)
            eng.tick()
        snap = eng.end_period()
        sys_load = snap.system_load(eng.router.table)
        if load_index_base is None and p >= 1:
            load_index_base = max(sys_load, 1e-9)
        if p >= 1:
            plan = cola_allocate(snap, seed=p)
            mp = plan_from_allocations(snap, plan.alloc)
            execute_plan(mp, eng)
            metrics = {
                "collocation": snap.collocation_factor(eng.router.table),
                "avg_ld": snap.load_distance(eng.router.table),
                "load_index": 100.0 * sys_load / load_index_base,
                "migrations_per_spl": mp.num_migrations,
            }
    return metrics


def run(quick: bool = False) -> list[str]:
    rows = []
    tp_kgs, tp_rate, tp_ticks = (40, 2_000.0, 8) if quick else (100, 8_000.0, 30)
    for job_key in THROUGHPUT_JOBS:
        m = measure_job_throughput(job_key, kgs=tp_kgs, rate=tp_rate, ticks=tp_ticks)
        rows.append(
            csv_row(
                f"real_jobs/{job_key}_seg_throughput",
                m["seg_us_per_tick"],
                f"tuples_per_sec={m['seg_tps']:.0f}"
                f";object_tuples_per_sec={m['obj_tps']:.0f}"
                f";fn_tuples_per_sec={m['fn_tps']:.0f}"
                f";pre_pr_tuples_per_sec={m['legacy_tps']:.0f}"
                f";speedup_vs_pre_pr={m['speedup']:.2f}"
                f";columnar_vs_object={m['obj_speedup']:.2f}"
                f";speedup_vs_fn={m['fn_speedup']:.2f}",
            )
        )
    jit_rate = 4_000.0 if quick else 8_000.0
    for job_key in JIT_JOBS:
        m = measure_job_jit(
            job_key, kgs=tp_kgs, rate=jit_rate, ticks=tp_ticks
        )
        rows.append(
            csv_row(
                f"real_jobs/{job_key}_jit_throughput",
                m["jit_us_per_tick"],
                f"tuples_per_sec={m['jit']:.0f}"
                f";seg_tuples_per_sec={m['seg']:.0f}"
                f";jit_vs_seg={m['jit_vs_seg']:.2f}"
                f";compile_s={m.get('compile_s', 0.0):.2f}",
            )
        )
    mig_kw = dict(kgs=16, n_tuples=6_000, repeats=2) if quick else {}
    mig = measure_migration_roundtrip(**mig_kw)
    rows.append(
        csv_row(
            "real_jobs/job2_migration_roundtrip",
            mig["typed"] * 1e6,
            f"object_us={mig['obj'] * 1e6:.0f}"
            f";typed_vs_object={mig['speedup']:.2f}"
            f";typed_blob_bytes={mig['typed_bytes']:.0f}"
            f";object_blob_bytes={mig['obj_bytes']:.0f}",
        )
    )
    kgs, nodes = (16, 4) if quick else (30, 8)
    periods, ticks = (5, 8) if quick else (8, 10)
    jobs = ["job2_fig12"] if quick else list(JOBS)
    for job_key in jobs:
        for method, fn in (("albic", run_albic), ("cola", run_cola)):
            t0 = time.perf_counter()
            m = fn(job_key, kgs, nodes, periods, ticks)
            dt = (time.perf_counter() - t0) / periods
            rows.append(
                csv_row(
                    f"real_jobs/{job_key}/{method}",
                    dt * 1e6,
                    ";".join(
                        f"{k}={v:.1f}" for k, v in m.items()
                    ),
                )
            )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()

"""Figs 12–14: Real Jobs 2–4 on the live engine — ALBIC vs COLA timelines of
collocation factor, load distance, load index and migrations."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import AdaptationFramework, AlbicParams
from repro.core.migration import execute_plan, plan_from_allocations
from repro.core.baselines import cola_allocate
from repro.data import airline_stream, real_job_2, real_job_3, real_job_4
from repro.data.synthetic import StreamSpec, weather_stream
from repro.engine import Controller, ControllerConfig, Engine

JOBS = {
    "job2_fig12": (real_job_2, ("airline",)),
    "job3_fig13": (real_job_3, ("airline",)),
    "job4_fig14": (real_job_4, ("airline", "weather")),
}


def build(job_key: str, kgs: int, nodes: int, seed: int):
    job_fn, sources = JOBS[job_key]
    topo = job_fn(keygroups_per_op=kgs)
    g = topo.num_keygroups
    # Anti-collocated initial allocation (paper: minimal initial collocation).
    alloc = np.zeros(g, dtype=np.int64)
    for op in range(topo.num_operators):
        base = topo.kg_base(op)
        n_op = topo.operators[op].num_keygroups
        alloc[base : base + n_op] = (np.arange(n_op) + op * (nodes // 2 + 1)) % nodes
    eng = Engine(
        topo,
        nodes,
        initial_alloc=alloc,
        ser_cost=0.6,
        service_rate=3000.0,
        seed=seed,
        collect_sinks=False,  # long runs: don't accumulate sink tuples
    )
    air = airline_stream(StreamSpec(rate=220.0, seed=seed))
    wx = weather_stream(StreamSpec(rate=80.0, seed=seed))

    def feeder(engine, tick):
        k, v, ts = next(air)
        engine.push_source("airline", k, v, ts)
        if "weather" in sources:
            k, v, ts = next(wx)
            engine.push_source("weather", k, v, ts)

    return eng, feeder


def run_albic(job_key, kgs, nodes, periods, ticks):
    eng, feeder = build(job_key, kgs, nodes, seed=2)
    ctl = Controller(
        eng,
        AdaptationFramework(
            mode="albic",
            max_migrations=10,
            albic_params=AlbicParams(max_ld=15.0, time_limit=1.5),
        ),
        ControllerConfig(ticks_per_period=ticks),
        feeder=feeder,
    )
    for _ in range(periods):
        m = ctl.period()
    h = ctl.history
    return {
        "collocation": m.collocation_factor,
        "avg_ld": float(np.mean([x.load_distance for x in h[1:]])),
        "load_index": m.load_index,
        "migrations_per_spl": float(np.mean([x.num_migrations for x in h[1:]])),
    }


def run_cola(job_key, kgs, nodes, periods, ticks):
    eng, feeder = build(job_key, kgs, nodes, seed=2)
    load_index_base = None
    metrics = {}
    for p in range(periods):
        for t in range(ticks):
            feeder(eng, t)
            eng.tick()
        snap = eng.end_period()
        sys_load = snap.system_load(eng.router.table)
        if load_index_base is None and p >= 1:
            load_index_base = max(sys_load, 1e-9)
        if p >= 1:
            plan = cola_allocate(snap, seed=p)
            mp = plan_from_allocations(snap, plan.alloc)
            execute_plan(mp, eng)
            metrics = {
                "collocation": snap.collocation_factor(eng.router.table),
                "avg_ld": snap.load_distance(eng.router.table),
                "load_index": 100.0 * sys_load / load_index_base,
                "migrations_per_spl": mp.num_migrations,
            }
    return metrics


def run(quick: bool = False) -> list[str]:
    rows = []
    kgs, nodes = (16, 4) if quick else (30, 8)
    periods, ticks = (5, 8) if quick else (8, 10)
    jobs = ["job2_fig12"] if quick else list(JOBS)
    for job_key in jobs:
        for method, fn in (("albic", run_albic), ("cola", run_cola)):
            t0 = time.perf_counter()
            m = fn(job_key, kgs, nodes, periods, ticks)
            dt = (time.perf_counter() - t0) / periods
            rows.append(
                csv_row(
                    f"real_jobs/{job_key}/{method}",
                    dt * 1e6,
                    ";".join(
                        f"{k}={v:.1f}" for k, v in m.items()
                    ),
                )
            )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
